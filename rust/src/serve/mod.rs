//! Random-access dataset serving over the decoded-block cache — the
//! first subsystem where one stored dataset is exercised by many
//! concurrent clients instead of one batch load.
//!
//! A [`DatasetReader`] (from
//! [`Dataset::reader`](crate::coordinator::Dataset::reader)) answers
//! rectangle, row-slice, nonzero-count and SpMV queries against a stored
//! dataset. Per stored file it parses the block directory **once** at
//! open ([`BlockDirectory`]); a query then
//!
//! 1. geometrically prunes the directory — only blocks whose global
//!    rectangle intersects the query rectangle are considered (the same
//!    intersection contract as block-pruned loading);
//! 2. claims each surviving block from the shared
//!    [`BlockCache`]: T1 hits are served from memory and **never touch
//!    storage**; a claim that finds the block's *encoded* payload in T2
//!    re-decodes it in memory (a decode paid, an I/O round trip saved —
//!    `decode_saves` in the stats); true misses are fetched through the
//!    VFS read-ahead pipeline
//!    ([`fetch_blocks`](crate::abhsf::load::fetch_blocks)) and
//!    published, and blocks already being decoded by another thread are
//!    awaited (single-flight coalescing);
//! 3. filters the block's decoded elements down to the query rectangle
//!    — or, for SpMV, executes the block's **scheme-native payload**
//!    through its per-scheme kernel (`crate::spmv::kernels`) with no
//!    triplet expansion.
//!
//! **Deadlock freedom.** A query claims, fetches and publishes all of
//! its misses for file `i` before waiting on any of file `i`'s in-flight
//! blocks, and every reader walks files in ascending index order. A
//! file-`i` flight is therefore always published by a loader whose only
//! possible blocking is on files `< i`, so waits terminate by induction
//! on the file index.
//!
//! [`run_closed_loop`] is the multi-threaded serving harness behind the
//! `serve` CLI subcommand and `benches/serve.rs`: N worker threads, each
//! with its own readers over the shared cache, issue seeded random
//! queries under a configurable [`Workload`] — uniform fresh spans, a
//! Zipfian distribution over a fixed template pool (every thread
//! derives the *same* pool from the master seed, so the hot set is
//! common), or a 90/10 hotspot — and report throughput, latency
//! percentiles, cache counters and a per-dataset breakdown as a
//! [`ServeReport`].

use std::ops::Range;
use std::str::FromStr;
use std::sync::Arc;
use std::time::Instant;

use crate::abhsf::load::{default_batch_bytes, fetch_decoded_blocks_batched, BlockDirectory};
use crate::abhsf::matrix_file_path;
use crate::cache::{
    BlockCache, BlockKey, CachedBlock, Claim, DatasetStats, EncodedBlock, FlightWaiter, LoadToken,
};
use crate::coordinator::error::DatasetError;
use crate::coordinator::metrics::ServeReport;
use crate::coordinator::Dataset;
use crate::h5::{H5Reader, IoStats};
use crate::mapping::rects_intersect;
use crate::obs::metrics::{HistogramSnapshot, LogHistogram};
use crate::obs::trace::{self, Tag};
use crate::util::rng::Xoshiro256;

/// One stored file's open handle, its parsed block directory, and the
/// file's read-ahead batch size (a per-file constant derived from its
/// chunk tables — computed once at open, not per query).
struct FileSlot {
    reader: H5Reader,
    dir: BlockDirectory,
    batch_bytes: u64,
}

/// Random-access cached reader over one [`Dataset`] (module docs for the
/// query path and the concurrency contract).
///
/// A reader is cheap relative to a load — opening parses each file's
/// block directory but fetches no payload — and is **not** shared across
/// threads: each serving thread opens its own reader against the shared
/// [`BlockCache`], which is where all cross-thread state lives.
pub struct DatasetReader<'c> {
    cache: &'c BlockCache,
    dataset_id: u64,
    dims: (u64, u64),
    files: Vec<FileSlot>,
}

impl<'c> DatasetReader<'c> {
    /// Open a reader: parse every stored file's block directory (no
    /// payload fetched) and register the dataset with the cache.
    pub fn open(dataset: &Dataset, cache: &'c BlockCache) -> Result<Self, DatasetError> {
        let storage = dataset.storage();
        let dataset_id = cache.dataset_id(storage.medium(), &storage.canonical(dataset.dir()));
        let mut files = Vec::with_capacity(dataset.nprocs());
        for k in 0..dataset.nprocs() {
            let path = matrix_file_path(dataset.dir(), k);
            let reader = H5Reader::open_on(storage.as_ref(), &path)
                .map_err(|e| DatasetError::Internal(Box::new(e)))?;
            let dir = BlockDirectory::read(&reader)
                .map_err(|e| DatasetError::Internal(Box::new(e)))?;
            let batch_bytes = default_batch_bytes(&reader);
            files.push(FileSlot {
                reader,
                dir,
                batch_bytes,
            });
        }
        Ok(Self {
            cache,
            dataset_id,
            dims: dataset.dims(),
            files,
        })
    }

    /// Global shape `(m, n)` of the served matrix.
    pub fn dims(&self) -> (u64, u64) {
        self.dims
    }

    /// The cache this reader serves through.
    pub fn cache(&self) -> &'c BlockCache {
        self.cache
    }

    /// Aggregate I/O counters of this reader's file handles — every byte
    /// this reader ever took from storage (directory parsing at open plus
    /// cache-miss fetches; hits add nothing).
    pub fn io_stats(&self) -> IoStats {
        let mut io = IoStats::default();
        for f in &self.files {
            io.add(f.reader.stats());
        }
        io
    }

    /// Visit every cached-or-fetched block intersecting `rect`, in
    /// ascending file order (the module-level deadlock-freedom contract
    /// lives here).
    fn gather<F>(&self, rect: (u64, u64, u64, u64), mut emit: F) -> Result<(), DatasetError>
    where
        F: FnMut(&Arc<CachedBlock>),
    {
        for (fi, slot) in self.files.iter().enumerate() {
            let mut hits: Vec<Arc<CachedBlock>> = Vec::new();
            let mut miss: Vec<usize> = Vec::new();
            let mut tokens: Vec<LoadToken<'_>> = Vec::new();
            let mut waiters: Vec<FlightWaiter> = Vec::new();
            for k in 0..slot.dir.entries.len() {
                if !rects_intersect(slot.dir.global_rect(k), rect) {
                    continue;
                }
                let e = &slot.dir.entries[k];
                let key = BlockKey {
                    dataset: self.dataset_id,
                    file: fi as u32,
                    brow: e.brow as u32,
                    bcol: e.bcol as u32,
                };
                match self.cache.claim(key) {
                    Claim::Hit(block) => hits.push(block),
                    Claim::Miss(mut token) => match token.take_encoded() {
                        // T2 revival: the claim carried the evicted
                        // block's encoded payload — re-decode in memory
                        // and publish, no storage round trip.
                        Some(enc) => hits.push(revive(token, &enc)?),
                        None => {
                            miss.push(k);
                            tokens.push(token);
                        }
                    },
                    Claim::InFlight(waiter) => waiters.push(waiter),
                }
            }
            for block in &hits {
                emit(block);
            }
            if !miss.is_empty() {
                // Cache misses go through the read-ahead pipeline; each
                // decoded block is published before the next is decoded,
                // so coalesced waiters unblock as early as possible. On a
                // fetch error the unconsumed tokens are dropped, which
                // fails their flights — waiters in other threads error
                // out instead of hanging.
                let mut pending = tokens.into_iter();
                fetch_decoded_blocks_batched(
                    &slot.reader,
                    &slot.dir,
                    &miss,
                    slot.batch_bytes,
                    |_, decoded| {
                        let token = pending.next().expect("one token per missed block");
                        let block = token.publish(decoded);
                        emit(&block);
                    },
                )
                .map_err(|e| DatasetError::Internal(Box::new(e)))?;
            }
            for waiter in waiters {
                let block = waiter
                    .wait()
                    .map_err(|e| DatasetError::Internal(e.into()))?;
                emit(&block);
            }
        }
        Ok(())
    }

    /// Number of stored files this reader serves.
    pub fn file_count(&self) -> usize {
        self.files.len()
    }

    /// The bounding window of stored file `file` as
    /// `(rows, cols) = ((r0, r1), (c0, c1))` half-open global ranges —
    /// the union of its directory's block rectangles. An empty file
    /// answers `((0, 0), (0, 0))`. This is what the distributed engine
    /// declares as a block-backed rank's row/column window: no payload is
    /// fetched, only the directory (already parsed at open) is walked.
    pub fn file_window(&self, file: usize) -> ((u64, u64), (u64, u64)) {
        let dir = &self.files[file].dir;
        if dir.entries.is_empty() {
            return ((0, 0), (0, 0));
        }
        let (mut r0, mut r1, mut c0, mut c1) = (u64::MAX, 0u64, u64::MAX, 0u64);
        for k in 0..dir.entries.len() {
            let (br, bc, bm, bn) = dir.global_rect(k);
            r0 = r0.min(br);
            r1 = r1.max(br + bm);
            c0 = c0.min(bc);
            c1 = c1.max(bc + bn);
        }
        ((r0, r1), (c0, c1))
    }

    /// Every decoded block of stored file `file`, **in directory order**
    /// — regardless of which blocks were cache hits, misses or coalesced
    /// flights when the call ran. The distributed engine applies a file's
    /// blocks in exactly this order on every iteration, which is what
    /// makes a block-backed SpMV bit-reproducible across runs and cache
    /// states (DESIGN.md §13); `gather`'s hits-then-misses-then-waiters
    /// emission order would not be.
    pub fn file_blocks(&self, file: usize) -> Result<Vec<Arc<CachedBlock>>, DatasetError> {
        let slot = &self.files[file];
        let nblocks = slot.dir.entries.len();
        let mut out: Vec<Option<Arc<CachedBlock>>> = vec![None; nblocks];
        let mut miss: Vec<usize> = Vec::new();
        let mut tokens: Vec<LoadToken<'_>> = Vec::new();
        let mut waiters: Vec<(usize, FlightWaiter)> = Vec::new();
        for k in 0..nblocks {
            let e = &slot.dir.entries[k];
            let key = BlockKey {
                dataset: self.dataset_id,
                file: file as u32,
                brow: e.brow as u32,
                bcol: e.bcol as u32,
            };
            match self.cache.claim(key) {
                Claim::Hit(block) => out[k] = Some(block),
                Claim::Miss(mut token) => match token.take_encoded() {
                    Some(enc) => out[k] = Some(revive(token, &enc)?),
                    None => {
                        miss.push(k);
                        tokens.push(token);
                    }
                },
                Claim::InFlight(waiter) => waiters.push((k, waiter)),
            }
        }
        if !miss.is_empty() {
            let mut pending = tokens.into_iter();
            fetch_decoded_blocks_batched(
                &slot.reader,
                &slot.dir,
                &miss,
                slot.batch_bytes,
                |k, decoded| {
                    let token = pending.next().expect("one token per missed block");
                    out[k] = Some(token.publish(decoded));
                },
            )
            .map_err(|e| DatasetError::Internal(Box::new(e)))?;
        }
        for (k, waiter) in waiters {
            out[k] = Some(
                waiter
                    .wait()
                    .map_err(|e| DatasetError::Internal(e.into()))?,
            );
        }
        Ok(out
            .into_iter()
            .map(|b| b.expect("every directory block claimed"))
            .collect())
    }

    /// All nonzeros with `row ∈ rows` and `col ∈ cols`, in global
    /// coordinates, sorted lexicographically.
    pub fn rect(
        &self,
        rows: Range<u64>,
        cols: Range<u64>,
    ) -> Result<Vec<(u64, u64, f64)>, DatasetError> {
        let q = (
            rows.start,
            cols.start,
            rows.end.saturating_sub(rows.start),
            cols.end.saturating_sub(cols.start),
        );
        let mut out: Vec<(u64, u64, f64)> = Vec::new();
        self.gather(q, |block| {
            block.for_each_element(|i, j, v| {
                if i >= rows.start && i < rows.end && j >= cols.start && j < cols.end {
                    out.push((i, j, v));
                }
            });
        })?;
        out.sort_unstable_by_key(|e| (e.0, e.1));
        Ok(out)
    }

    /// All nonzeros of the row band `rows` (every column).
    pub fn row_slice(&self, rows: Range<u64>) -> Result<Vec<(u64, u64, f64)>, DatasetError> {
        let n = self.dims.1;
        self.rect(rows, 0..n)
    }

    /// Count the nonzeros inside the rectangle without materializing
    /// them (the blocks still have to be resident or fetched — counting
    /// is a decode-side operation in ABHSF, not a directory-side one,
    /// because a block's rectangle only bounds where its `zeta` elements
    /// may lie).
    pub fn nnz_in(&self, rows: Range<u64>, cols: Range<u64>) -> Result<u64, DatasetError> {
        let q = (
            rows.start,
            cols.start,
            rows.end.saturating_sub(rows.start),
            cols.end.saturating_sub(cols.start),
        );
        let mut count = 0u64;
        self.gather(q, |block| {
            block.for_each_element(|i, j, _| {
                if i >= rows.start && i < rows.end && j >= cols.start && j < cols.end {
                    count += 1;
                }
            });
        })?;
        Ok(count)
    }

    /// `y = A x` over the whole matrix, through the cache: every block is
    /// claimed (fetching only the absent ones) and accumulated through
    /// the per-scheme kernels via
    /// [`SpmvParts::Blocks`](crate::spmv::SpmvParts) — each cached
    /// payload executes directly, **never** expanding to triplets.
    /// Blocks stream through one at a time, so the query's resident set
    /// stays bounded by the cache budget plus one block, not the whole
    /// decoded matrix.
    pub fn spmv(&self, x: &[f64]) -> Result<Vec<f64>, DatasetError> {
        let (m, n) = self.dims;
        let mut y = vec![0.0; m as usize];
        self.gather((0, 0, m, n), |block| {
            let one = [block.block()];
            crate::spmv::SpmvParts::Blocks {
                m,
                n,
                blocks: &one,
            }
            .spmv_into(x, &mut y);
        })?;
        Ok(y)
    }
}

/// Publish a T2-carried encoded payload: re-decode in memory through
/// the same validated constructors the fetch path uses. A decode error
/// here means the cached bytes are corrupt — fail the flight (so
/// coalesced waiters error out instead of hanging) and surface it.
fn revive(token: LoadToken<'_>, enc: &EncodedBlock) -> Result<Arc<CachedBlock>, DatasetError> {
    match enc.decode() {
        Ok(decoded) => Ok(token.publish(decoded)),
        Err(e) => {
            token.fail(format!("T2 payload re-decode failed: {e}"));
            Err(DatasetError::Internal(Box::new(e)))
        }
    }
}

/// Query-key distribution of a [`run_closed_loop`] run.
///
/// Non-uniform workloads draw from a per-dataset pool of
/// [`TEMPLATE_POOL`] seeded query templates that every worker thread
/// derives identically from the master seed — the hot set is *shared*,
/// which is what makes skew cache-relevant (each thread hammering a
/// private hot set would never contend for the same blocks).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Workload {
    /// Fresh random span per query (the historical behavior).
    Uniform,
    /// Template ranks drawn with probability ∝ 1/rankᶿ (θ > 0; θ ≈ 1.1
    /// is the classic heavy skew where a handful of templates dominate).
    Zipf(f64),
    /// 90% of queries hit the first `K` templates, 10% spread uniformly
    /// over the whole pool.
    Hotspot(u64),
}

impl Default for Workload {
    fn default() -> Self {
        Workload::Uniform
    }
}

impl FromStr for Workload {
    type Err = String;

    /// `uniform` | `zipf:THETA` | `hotspot:K`.
    fn from_str(s: &str) -> Result<Self, String> {
        if s == "uniform" {
            return Ok(Workload::Uniform);
        }
        if let Some(theta) = s.strip_prefix("zipf:") {
            let theta: f64 = theta
                .parse()
                .map_err(|_| format!("bad zipf exponent {theta:?}"))?;
            if !theta.is_finite() || theta <= 0.0 {
                return Err(format!("zipf exponent must be finite and > 0, got {theta}"));
            }
            return Ok(Workload::Zipf(theta));
        }
        if let Some(k) = s.strip_prefix("hotspot:") {
            let k: u64 = k.parse().map_err(|_| format!("bad hotspot size {k:?}"))?;
            if k == 0 {
                return Err("hotspot size must be >= 1".to_string());
            }
            return Ok(Workload::Hotspot(k));
        }
        Err(format!(
            "unknown workload {s:?} (expected uniform | zipf:THETA | hotspot:K)"
        ))
    }
}

impl std::fmt::Display for Workload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Workload::Uniform => write!(f, "uniform"),
            Workload::Zipf(theta) => write!(f, "zipf:{theta}"),
            Workload::Hotspot(k) => write!(f, "hotspot:{k}"),
        }
    }
}

/// Size of the per-dataset query-template pool non-uniform workloads
/// draw from.
pub const TEMPLATE_POOL: usize = 64;

/// One reusable query shape: a rectangle plus which query kind runs it
/// (same 1-in-4 kind mix as the uniform stream).
#[derive(Debug, Clone)]
struct QueryTemplate {
    rows: Range<u64>,
    cols: Range<u64>,
    kind: u64,
}

/// The shared template pool of dataset `di`: a pure function of the
/// master seed and the dataset's index+dims, so every thread (and every
/// same-seed run) sees the same templates in the same rank order.
fn template_pool(seed: u64, di: usize, dims: (u64, u64)) -> Vec<QueryTemplate> {
    let mut rng = Xoshiro256::seed_from_u64(
        seed ^ 0xA076_1D64_78BD_642F ^ (di as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    (0..TEMPLATE_POOL)
        .map(|_| QueryTemplate {
            rows: random_span(&mut rng, dims.0),
            cols: random_span(&mut rng, dims.1),
            kind: rng.next_below(4),
        })
        .collect()
}

/// Zipf rank sampler: cumulative weights `Σ 1/rankᶿ`, inverted by
/// binary search — O(log n) per draw, no rejection.
struct ZipfRanks {
    cum: Vec<f64>,
}

impl ZipfRanks {
    fn new(n: usize, theta: f64) -> Self {
        let mut cum = Vec::with_capacity(n);
        let mut total = 0.0;
        for rank in 1..=n {
            total += (rank as f64).powf(-theta);
            cum.push(total);
        }
        Self { cum }
    }

    fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let total = *self.cum.last().expect("non-empty pool");
        let u = rng.next_f64() * total;
        self.cum
            .partition_point(|&c| c <= u)
            .min(self.cum.len() - 1)
    }
}

/// Configuration of one [`run_closed_loop`] serving run.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads (each opens its own readers; min 1).
    pub threads: usize,
    /// Total queries across all threads.
    pub queries: u64,
    /// Master seed; thread `t` derives its private query stream from it.
    pub seed: u64,
    /// Every `spmv_every`-th query of a thread is a whole-matrix SpMV
    /// (`0` disables SpMV queries).
    pub spmv_every: u64,
    /// Query-key distribution (see [`Workload`]).
    pub workload: Workload,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            threads: 4,
            queries: 200,
            seed: 42,
            spmv_every: 16,
            workload: Workload::Uniform,
        }
    }
}

/// Per-thread tallies, merged into the final [`ServeReport`]. Latencies
/// are bucketed into a private per-thread histogram as queries complete
/// — O(buckets) memory however many queries run, no cross-thread
/// contention, and the exact maximum is preserved.
struct ThreadOut {
    latency: HistogramSnapshot,
    elements: u64,
    spmvs: u64,
    io: IoStats,
}

/// Run the closed-loop serving harness: `cfg.threads` workers issue
/// `cfg.queries` seeded random queries (rect / row-slice / nnz, plus a
/// whole-matrix SpMV every `cfg.spmv_every`-th query) against `datasets`
/// through the shared `cache`. Returns throughput, latency percentiles,
/// aggregate reader I/O and the cache counters.
pub fn run_closed_loop(
    datasets: &[Dataset],
    cache: &BlockCache,
    cfg: &ServeConfig,
) -> Result<ServeReport, DatasetError> {
    assert!(!datasets.is_empty(), "no datasets to serve");
    let threads = cfg.threads.max(1);
    let per_thread: Vec<u64> = (0..threads as u64)
        .map(|t| cfg.queries / threads as u64 + u64::from(t < cfg.queries % threads as u64))
        .collect();
    let t0 = Instant::now();
    let results: Vec<Result<ThreadOut, DatasetError>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (t, &share) in per_thread.iter().enumerate() {
            handles.push(scope.spawn(move || worker(datasets, cache, cfg, t, share)));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("serve worker panicked"))
            .collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let mut latency = HistogramSnapshot::empty();
    let mut elements = 0u64;
    let mut spmvs = 0u64;
    let mut io = IoStats::default();
    for r in results {
        let out = r?;
        latency = latency.merge(&out.latency);
        elements += out.elements;
        spmvs += out.spmvs;
        io.add(out.io);
    }
    // Publish this run into the process-wide registry before reporting.
    let reg = crate::obs::metrics::global();
    reg.histogram("serve.latency_s").merge_snapshot(&latency);
    reg.counter("serve.queries").add(latency.count);
    reg.counter("serve.spmv_queries").add(spmvs);
    let (p50_ms, p90_ms, p99_ms, p999_ms, max_ms) = (
        latency.quantile(0.50) * 1e3,
        latency.quantile(0.90) * 1e3,
        latency.quantile(0.99) * 1e3,
        latency.quantile(0.999) * 1e3,
        latency.max * 1e3,
    );
    // Per-dataset breakdown: same id derivation as `DatasetReader::open`,
    // so this re-lookup is a pure read of already-registered ids.
    let per_dataset: Vec<(String, DatasetStats)> = datasets
        .iter()
        .map(|d| {
            let storage = d.storage();
            let id = cache.dataset_id(storage.medium(), &storage.canonical(d.dir()));
            (d.dir().display().to_string(), cache.dataset_stats(id))
        })
        .collect();
    Ok(ServeReport {
        threads,
        queries: latency.count,
        spmv_queries: spmvs,
        wall_s,
        p50_ms,
        p90_ms,
        p99_ms,
        p999_ms,
        max_ms,
        elements_returned: elements,
        io,
        cache: cache.stats(),
        per_dataset,
    })
}

/// One worker: open private readers, run `share` seeded queries.
fn worker(
    datasets: &[Dataset],
    cache: &BlockCache,
    cfg: &ServeConfig,
    t: usize,
    share: u64,
) -> Result<ThreadOut, DatasetError> {
    let mut readers = Vec::with_capacity(datasets.len());
    for d in datasets {
        readers.push(d.reader(cache)?);
    }
    // Distinct, reproducible stream per thread.
    let mut rng =
        Xoshiro256::seed_from_u64(cfg.seed ^ (t as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let latency = LogHistogram::new();
    let mut out = ThreadOut {
        latency: HistogramSnapshot::empty(),
        elements: 0,
        spmvs: 0,
        io: IoStats::default(),
    };
    // Shared query-template pools (identical in every thread — pure
    // function of the master seed) and the Zipf rank table, built once.
    let pools: Vec<Vec<QueryTemplate>> = readers
        .iter()
        .enumerate()
        .map(|(di, r)| template_pool(cfg.seed, di, r.dims()))
        .collect();
    let zipf = match cfg.workload {
        Workload::Zipf(theta) => Some(ZipfRanks::new(TEMPLATE_POOL, theta)),
        _ => None,
    };
    for q in 0..share {
        let di = rng.next_below(readers.len() as u64) as usize;
        let reader = &readers[di];
        let (m, n) = reader.dims();
        let is_spmv = cfg.spmv_every > 0 && (q + 1) % cfg.spmv_every == 0;
        let q0 = Instant::now();
        if is_spmv {
            let _span = trace::span(
                "query",
                &[("kq", Tag::S("spmv")), ("dataset", Tag::U(di as u64))],
            );
            let x: Vec<f64> = (0..n).map(|i| ((i % 13) as f64) * 0.25 + 0.5).collect();
            let y = reader.spmv(&x)?;
            out.elements += y.len() as u64;
            out.spmvs += 1;
        } else {
            let (rows, cols, kind) = match cfg.workload {
                Workload::Uniform => (
                    random_span(&mut rng, m),
                    random_span(&mut rng, n),
                    rng.next_below(4),
                ),
                Workload::Zipf(_) => {
                    let t = &pools[di][zipf.as_ref().expect("zipf table built").sample(&mut rng)];
                    (t.rows.clone(), t.cols.clone(), t.kind)
                }
                Workload::Hotspot(k) => {
                    let pool = &pools[di];
                    let hot = (k as usize).clamp(1, pool.len()) as u64;
                    let idx = if rng.chance(0.9) {
                        rng.next_below(hot)
                    } else {
                        rng.next_below(pool.len() as u64)
                    };
                    let t = &pool[idx as usize];
                    (t.rows.clone(), t.cols.clone(), t.kind)
                }
            };
            let kq = match kind {
                0 => "nnz_in",
                1 => "row_slice",
                _ => "rect",
            };
            let _span = trace::span(
                "query",
                &[("kq", Tag::S(kq)), ("dataset", Tag::U(di as u64))],
            );
            match kind {
                0 => out.elements += reader.nnz_in(rows, cols)?,
                1 => out.elements += reader.row_slice(rows)?.len() as u64,
                _ => out.elements += reader.rect(rows, cols)?.len() as u64,
            }
        }
        latency.record(q0.elapsed().as_secs_f64());
    }
    out.latency = latency.snapshot();
    for r in &readers {
        out.io.add(r.io_stats());
    }
    Ok(out)
}

/// A random sub-range of `[0, extent)` spanning between 1 element and
/// half the extent — big enough to touch several blocks, small enough
/// that distinct queries have distinct footprints.
fn random_span(rng: &mut Xoshiro256, extent: u64) -> Range<u64> {
    let extent = extent.max(1);
    let span = 1 + rng.next_below(extent.div_ceil(2));
    let start = rng.next_below(extent - span + 1);
    start..start + span
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::percentile_sorted;

    /// The harness's histogram percentiles must stay pinned to the old
    /// exact-sort path (`percentile_sorted` over every latency) within
    /// the histogram's advertised error bound, on a latency-shaped
    /// seeded sample, and `max` must be exact — the contract that made
    /// it safe for `run_closed_loop` to drop its unbounded `Vec<f64>`.
    #[test]
    fn histogram_percentiles_match_exact_sort_path() {
        let mut rng = Xoshiro256::seed_from_u64(4242);
        let hist = LogHistogram::new();
        let mut exact: Vec<f64> = Vec::new();
        // Log-uniform 10 µs – 100 ms with a sparse 10× tail, the shape a
        // mixed cached/missed query stream produces.
        for i in 0..20_000 {
            let u = rng.next_f64();
            let mut v = 1e-5 * (1e4f64).powf(u);
            if i % 97 == 0 {
                v *= 10.0;
            }
            hist.record(v);
            exact.push(v);
        }
        exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let snap = hist.snapshot();
        assert_eq!(snap.count, exact.len() as u64);
        assert_eq!(snap.max, *exact.last().unwrap(), "max must be exact");
        for (q, pct) in [(0.50, 50.0), (0.90, 90.0), (0.99, 99.0), (0.999, 99.9)] {
            let old = percentile_sorted(&exact, pct);
            let new = snap.quantile(q);
            let rel = (new - old).abs() / old;
            // 2% histogram error + a small allowance for nearest-rank vs
            // the old path's linear interpolation between neighbors.
            assert!(
                rel <= 0.025,
                "p{pct}: histogram {new} vs exact-sort {old} (rel err {rel:.4})"
            );
        }
    }

    #[test]
    fn random_span_in_bounds() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        for extent in [1u64, 2, 7, 64, 1000] {
            for _ in 0..200 {
                let r = random_span(&mut rng, extent);
                assert!(r.start < r.end, "empty span for extent {extent}");
                assert!(r.end <= extent, "span {r:?} beyond extent {extent}");
                assert!(r.end - r.start <= extent.div_ceil(2));
            }
        }
    }

    #[test]
    fn workload_parses_and_displays() {
        assert_eq!("uniform".parse::<Workload>().unwrap(), Workload::Uniform);
        assert_eq!("zipf:1.1".parse::<Workload>().unwrap(), Workload::Zipf(1.1));
        assert_eq!("hotspot:8".parse::<Workload>().unwrap(), Workload::Hotspot(8));
        for bad in [
            "", "zipfian", "zipf:", "zipf:0", "zipf:-1", "zipf:nan", "hotspot:", "hotspot:0",
            "hotspot:x",
        ] {
            assert!(bad.parse::<Workload>().is_err(), "{bad:?} must not parse");
        }
        assert_eq!(Workload::Zipf(1.1).to_string(), "zipf:1.1");
        assert_eq!(Workload::Hotspot(4).to_string(), "hotspot:4");
        assert_eq!(Workload::default().to_string(), "uniform");
    }

    /// θ = 1.1 over a 64-template pool: the head ranks must dominate the
    /// draw mass (that concentration is what the two-tier bench
    /// exploits) while every rank stays reachable.
    #[test]
    fn zipf_sampler_skews_toward_low_ranks() {
        let z = ZipfRanks::new(TEMPLATE_POOL, 1.1);
        let mut rng = Xoshiro256::seed_from_u64(99);
        let mut counts = [0u64; TEMPLATE_POOL];
        let draws = 20_000u64;
        for _ in 0..draws {
            counts[z.sample(&mut rng)] += 1;
        }
        let head: u64 = counts[..8].iter().sum();
        assert!(
            head > draws / 2,
            "top-8 of {TEMPLATE_POOL} ranks must take over half the draws, got {head}/{draws}"
        );
        assert!(
            counts[0] > counts[TEMPLATE_POOL / 2].saturating_mul(5),
            "rank 0 ({}) must dwarf mid ranks ({})",
            counts[0],
            counts[TEMPLATE_POOL / 2]
        );
    }

    /// Template pools are a pure function of (seed, dataset index, dims)
    /// — the property that makes the hot set common across threads.
    #[test]
    fn template_pools_are_deterministic() {
        let a = template_pool(42, 1, (512, 512));
        let b = template_pool(42, 1, (512, 512));
        assert_eq!(a.len(), TEMPLATE_POOL);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((&x.rows, &x.cols, x.kind), (&y.rows, &y.cols, y.kind));
        }
        let c = template_pool(42, 2, (512, 512));
        assert!(
            a.iter()
                .zip(&c)
                .any(|(x, y)| x.rows != y.rows || x.cols != y.cols),
            "different dataset index must yield a different pool"
        );
    }
}
