//! Per-scheme block SpMV kernels over [`DecodedBlock`] payloads.
//!
//! Each kernel accumulates `y += A_block · x` straight from the block's
//! scheme-native payload — no expansion to `(row, col, val)` triplets.
//! This is where the ABHSF premise pays off at execution time: the CSR
//! kernel walks row pointers, the bitmap kernel scans occupancy bytes
//! LSB-first, the dense kernel strides row-major, and the COO kernel
//! scatters triplets, each touching exactly the bytes the cache stores.
//!
//! **Exactness contract**: every kernel applies its elements to `y` one
//! at a time (`y[i] += v * x[j]`), in the scheme's natural row-major
//! decode order — the same order and grouping
//! [`DecodedBlock::for_each_element`] emits and the generic
//! `SpmvParts::Elements` path applies. The per-scheme results are
//! therefore **bit-identical** to the generic path, not merely close:
//! no per-row scalar accumulators that would regroup f64 sums (their
//! grouping changes results when `y` starts dirty). The differential
//! harness (`rust/tests/kernels.rs`) asserts exact equality.

use crate::abhsf::load::DecodedBlock;

/// Accumulate `y += A_block · x` for one decoded block, dispatching to
/// the scheme's kernel. `x` and `y` are global vectors; the block's
/// [`geom`](DecodedBlock::geom) places it (`row0`/`col0` are global).
pub fn spmv_block_into(block: &DecodedBlock, x: &[f64], y: &mut [f64]) {
    spmv_block_windowed_into(block, x, 0, y, 0);
}

/// [`spmv_block_into`] over *windowed* vectors: `x_win` holds the global
/// entries `[x_off, x_off + x_win.len())` of `x`, `y_win` the global
/// entries `[y_off, y_off + y_win.len())` of `y`. The windows must cover
/// the block's geom. Element order is identical to the global form, so
/// the result bits match it exactly — the distributed engine applies
/// file-local blocks through here against halo-assembled windows.
pub fn spmv_block_windowed_into(
    block: &DecodedBlock,
    x_win: &[f64],
    x_off: u64,
    y_win: &mut [f64],
    y_off: u64,
) {
    let g = block.geom();
    assert!(
        y_off <= g.row0 && x_off <= g.col0,
        "window offsets ({y_off}, {x_off}) past block geom ({}, {})",
        g.row0,
        g.col0
    );
    match block {
        DecodedBlock::Coo {
            geom,
            lrows,
            lcols,
            vals,
        } => {
            let (r0, c0) = ((geom.row0 - y_off) as usize, (geom.col0 - x_off) as usize);
            for ((&lr, &lc), &v) in lrows.iter().zip(lcols).zip(vals) {
                y_win[r0 + lr as usize] += v * x_win[c0 + lc as usize];
            }
        }
        DecodedBlock::CsrInBlock {
            geom,
            rowptrs,
            lcolinds,
            vals,
        } => {
            let (r0, c0) = ((geom.row0 - y_off) as usize, (geom.col0 - x_off) as usize);
            for lr in 0..geom.s as usize {
                let (lo, hi) = (rowptrs[lr] as usize, rowptrs[lr + 1] as usize);
                for e in lo..hi {
                    y_win[r0 + lr] += vals[e] * x_win[c0 + lcolinds[e] as usize];
                }
            }
        }
        DecodedBlock::Bitmap { geom, bits, vals } => {
            let (r0, c0) = ((geom.row0 - y_off) as usize, (geom.col0 - x_off) as usize);
            let s = geom.s as usize;
            let mut next = 0usize;
            for (bi, &byte) in bits.iter().enumerate() {
                let mut rest = byte;
                while rest != 0 {
                    let cell = bi * 8 + rest.trailing_zeros() as usize;
                    y_win[r0 + cell / s] += vals[next] * x_win[c0 + cell % s];
                    next += 1;
                    rest &= rest - 1;
                }
            }
        }
        DecodedBlock::Dense { geom, vals } => {
            let (r0, c0) = ((geom.row0 - y_off) as usize, (geom.col0 - x_off) as usize);
            let s = geom.s as usize;
            for (lr, row) in vals.chunks_exact(s).enumerate() {
                for (lc, &v) in row.iter().enumerate() {
                    // Skipping zeros keeps the summation stream identical
                    // to the triplet path (and edge blocks' unused cells
                    // must not touch y at all).
                    if v != 0.0 {
                        y_win[r0 + lr] += v * x_win[c0 + lc];
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abhsf::Scheme;

    /// Fixed 4x4 pattern exercised under every scheme.
    fn elems() -> Vec<(u16, u16, f64)> {
        vec![
            (0, 0, 2.0),
            (0, 3, 1.0),
            (1, 1, -1.5),
            (2, 0, 4.0),
            (3, 2, 0.5),
        ]
    }

    #[test]
    fn all_schemes_agree_with_triplets() {
        let x = [1.0, -2.0, 0.5, 3.0];
        for scheme in Scheme::ALL {
            let block = DecodedBlock::build(scheme, 0, 0, 4, &elems()).unwrap();
            let mut y = [0.25; 4]; // dirty start: kernels accumulate
            spmv_block_into(&block, &x, &mut y);
            let mut want = [0.25; 4];
            for (i, j, v) in block.elements() {
                want[i as usize] += v * x[j as usize];
            }
            assert_eq!(y, want, "{scheme:?}");
        }
    }

    #[test]
    fn offset_block_lands_in_global_rows() {
        let block = DecodedBlock::build(Scheme::Csr, 4, 4, 4, &elems()).unwrap();
        let x = [0.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0];
        let mut y = [0.0; 8];
        spmv_block_into(&block, &x, &mut y);
        assert_eq!(&y[0..4], &[0.0; 4]);
        assert_eq!(&y[4..8], &[3.0, -1.5, 4.0, 0.5]);
    }

    /// A windowed apply over exactly the block's span lands on the same
    /// bits as the global apply, for every scheme.
    #[test]
    fn windowed_apply_bitwise_matches_global() {
        let x = [0.0, 0.0, 0.0, 0.0, 1.5, -2.0, 0.25, 3.0];
        for scheme in Scheme::ALL {
            let block = DecodedBlock::build(scheme, 4, 4, 4, &elems()).unwrap();
            let mut y_global = [0.0f64; 8];
            spmv_block_into(&block, &x, &mut y_global);
            let mut y_win = [0.0f64; 4];
            spmv_block_windowed_into(&block, &x[4..8], 4, &mut y_win, 4);
            assert_eq!(&y_global[4..8], &y_win, "{scheme:?}");
        }
    }

    #[test]
    fn empty_block_is_a_noop() {
        for scheme in Scheme::ALL {
            let block = DecodedBlock::build(scheme, 0, 0, 3, &[]).unwrap();
            let mut y = [7.0; 3];
            spmv_block_into(&block, &[1.0; 3], &mut y);
            assert_eq!(y, [7.0; 3], "{scheme:?}");
        }
    }
}
