//! Per-scheme block SpMV kernels over [`DecodedBlock`] payloads.
//!
//! Each kernel accumulates `y += A_block · x` straight from the block's
//! scheme-native payload — no expansion to `(row, col, val)` triplets.
//! This is where the ABHSF premise pays off at execution time: the CSR
//! kernel walks row pointers, the bitmap kernel scans occupancy bytes
//! LSB-first, the dense kernel strides row-major, and the COO kernel
//! scatters triplets, each touching exactly the bytes the cache stores.
//!
//! **Exactness contract**: every kernel applies its elements to `y` one
//! at a time (`y[i] += v * x[j]`), in the scheme's natural row-major
//! decode order — the same order and grouping
//! [`DecodedBlock::for_each_element`] emits and the generic
//! `SpmvParts::Elements` path applies. The per-scheme results are
//! therefore **bit-identical** to the generic path, not merely close:
//! no per-row scalar accumulators that would regroup f64 sums (their
//! grouping changes results when `y` starts dirty). The differential
//! harness (`rust/tests/kernels.rs`) asserts exact equality.

use crate::abhsf::load::DecodedBlock;

/// Accumulate `y += A_block · x` for one decoded block, dispatching to
/// the scheme's kernel. `x` and `y` are global vectors; the block's
/// [`geom`](DecodedBlock::geom) places it (`row0`/`col0` are global).
pub fn spmv_block_into(block: &DecodedBlock, x: &[f64], y: &mut [f64]) {
    match block {
        DecodedBlock::Coo {
            geom,
            lrows,
            lcols,
            vals,
        } => {
            let (r0, c0) = (geom.row0 as usize, geom.col0 as usize);
            for ((&lr, &lc), &v) in lrows.iter().zip(lcols).zip(vals) {
                y[r0 + lr as usize] += v * x[c0 + lc as usize];
            }
        }
        DecodedBlock::CsrInBlock {
            geom,
            rowptrs,
            lcolinds,
            vals,
        } => {
            let (r0, c0) = (geom.row0 as usize, geom.col0 as usize);
            for lr in 0..geom.s as usize {
                let (lo, hi) = (rowptrs[lr] as usize, rowptrs[lr + 1] as usize);
                for e in lo..hi {
                    y[r0 + lr] += vals[e] * x[c0 + lcolinds[e] as usize];
                }
            }
        }
        DecodedBlock::Bitmap { geom, bits, vals } => {
            let (r0, c0) = (geom.row0 as usize, geom.col0 as usize);
            let s = geom.s as usize;
            let mut next = 0usize;
            for (bi, &byte) in bits.iter().enumerate() {
                let mut rest = byte;
                while rest != 0 {
                    let cell = bi * 8 + rest.trailing_zeros() as usize;
                    y[r0 + cell / s] += vals[next] * x[c0 + cell % s];
                    next += 1;
                    rest &= rest - 1;
                }
            }
        }
        DecodedBlock::Dense { geom, vals } => {
            let (r0, c0) = (geom.row0 as usize, geom.col0 as usize);
            let s = geom.s as usize;
            for (lr, row) in vals.chunks_exact(s).enumerate() {
                for (lc, &v) in row.iter().enumerate() {
                    // Skipping zeros keeps the summation stream identical
                    // to the triplet path (and edge blocks' unused cells
                    // must not touch y at all).
                    if v != 0.0 {
                        y[r0 + lr] += v * x[c0 + lc];
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abhsf::Scheme;

    /// Fixed 4x4 pattern exercised under every scheme.
    fn elems() -> Vec<(u16, u16, f64)> {
        vec![
            (0, 0, 2.0),
            (0, 3, 1.0),
            (1, 1, -1.5),
            (2, 0, 4.0),
            (3, 2, 0.5),
        ]
    }

    #[test]
    fn all_schemes_agree_with_triplets() {
        let x = [1.0, -2.0, 0.5, 3.0];
        for scheme in Scheme::ALL {
            let block = DecodedBlock::build(scheme, 0, 0, 4, &elems()).unwrap();
            let mut y = [0.25; 4]; // dirty start: kernels accumulate
            spmv_block_into(&block, &x, &mut y);
            let mut want = [0.25; 4];
            for (i, j, v) in block.elements() {
                want[i as usize] += v * x[j as usize];
            }
            assert_eq!(y, want, "{scheme:?}");
        }
    }

    #[test]
    fn offset_block_lands_in_global_rows() {
        let block = DecodedBlock::build(Scheme::Csr, 4, 4, 4, &elems()).unwrap();
        let x = [0.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0];
        let mut y = [0.0; 8];
        spmv_block_into(&block, &x, &mut y);
        assert_eq!(&y[0..4], &[0.0; 4]);
        assert_eq!(&y[4..8], &[3.0, -1.5, 4.0, 0.5]);
    }

    #[test]
    fn empty_block_is_a_noop() {
        for scheme in Scheme::ALL {
            let block = DecodedBlock::build(scheme, 0, 0, 3, &[]).unwrap();
            let mut y = [7.0; 3];
            spmv_block_into(&block, &[1.0; 3], &mut y);
            assert_eq!(y, [7.0; 3], "{scheme:?}");
        }
    }
}
