//! Native sparse matrix–vector products and distributed SpMV assembly —
//! the downstream workload that loaded matrices feed, and the oracle the
//! PJRT artifact path is validated against.

pub mod kernels;

use crate::abhsf::load::DecodedBlock;
use crate::formats::{Coo, Csr};

/// A distributed matrix in any of the in-memory part representations the
/// crate produces — the one SpMV kernel path shared by the CLI `spmv`
/// consumer (CSR parts from a [`crate::coordinator::LoadPlan`]), COO
/// loads, and the serving layer's cached reader
/// (`crate::serve::DatasetReader::spmv`), whose parts are decoded-block
/// element slices in **global** coordinates.
pub enum SpmvParts<'a> {
    /// Local CSR submatrices covering the global matrix.
    Csr(&'a [Csr]),
    /// Local COO submatrices covering the global matrix.
    Coo(&'a [Coo]),
    /// Raw `(row, col, value)` triplet slices in global coordinates
    /// (e.g. one slice per cached decoded block), with the global shape
    /// stated explicitly since the slices carry no metadata.
    Elements {
        /// Global rows.
        m: u64,
        /// Global columns.
        n: u64,
        /// The triplet slices; together they must cover each nonzero
        /// exactly once.
        parts: &'a [&'a [(u64, u64, f64)]],
    },
    /// Scheme-native decoded cache blocks: each executes through its
    /// per-scheme kernel ([`kernels::spmv_block_into`]) with **no
    /// triplet expansion** — the serving layer's
    /// (`crate::serve::DatasetReader::spmv`) production path.
    Blocks {
        /// Global rows.
        m: u64,
        /// Global columns.
        n: u64,
        /// The blocks; together they must cover each nonzero exactly
        /// once (their geoms place them in the global matrix).
        blocks: &'a [&'a DecodedBlock],
    },
}

impl SpmvParts<'_> {
    /// Global row count `m`.
    pub fn rows(&self) -> u64 {
        match self {
            SpmvParts::Csr(parts) => {
                assert!(!parts.is_empty(), "no local parts");
                parts[0].info.m
            }
            SpmvParts::Coo(parts) => {
                assert!(!parts.is_empty(), "no local parts");
                parts[0].info.m
            }
            SpmvParts::Elements { m, .. } => *m,
            SpmvParts::Blocks { m, .. } => *m,
        }
    }

    /// `y = A x` over all parts: allocates a zeroed `y`, then
    /// [`spmv_into`](Self::spmv_into) — the overwrite form callers use
    /// when they do not manage the output buffer themselves.
    pub fn spmv(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows() as usize];
        self.spmv_into(x, &mut y);
        y
    }

    /// **Accumulate** `y += A x` over all parts into a caller-owned
    /// global vector — `y` is *never* zeroed or overwritten here, for
    /// every variant. This is the streaming form: the serving layer
    /// feeds cached blocks through here one at a time, so a
    /// whole-matrix product never has to hold every decoded block alive
    /// at once — which only works because each call adds its parts'
    /// contribution to whatever is already in `y`. Callers reusing a
    /// buffer across iterations (the power-iteration loop) must clear
    /// it between products or use [`spmv`](Self::spmv); the contract is
    /// pinned by `rust/tests/kernels.rs`.
    pub fn spmv_into(&self, x: &[f64], y: &mut [f64]) {
        match self {
            SpmvParts::Csr(parts) => {
                for p in *parts {
                    p.spmv_into(x, y);
                }
            }
            SpmvParts::Coo(parts) => {
                for p in *parts {
                    p.spmv_into(x, y);
                }
            }
            SpmvParts::Elements { m, n, parts } => {
                assert_eq!(x.len() as u64, *n, "x length != n");
                assert_eq!(y.len() as u64, *m, "y length != m");
                for part in *parts {
                    for &(i, j, v) in *part {
                        y[i as usize] += v * x[j as usize];
                    }
                }
            }
            SpmvParts::Blocks { m, n, blocks } => {
                assert_eq!(x.len() as u64, *n, "x length != n");
                assert_eq!(y.len() as u64, *m, "y length != m");
                for block in *blocks {
                    kernels::spmv_block_into(block, x, y);
                }
            }
        }
    }
}

/// One normalized power-iteration step over any part representation:
/// `x' = A x / ‖A x‖₂`. Returns `(x', ‖A x‖₂)`.
pub fn power_iteration_step_parts(parts: &SpmvParts<'_>, x: &[f64]) -> (Vec<f64>, f64) {
    let y = parts.spmv(x);
    let norm = y.iter().map(|v| v * v).sum::<f64>().sqrt();
    if norm == 0.0 {
        return (y, 0.0);
    }
    (y.iter().map(|v| v / norm).collect(), norm)
}

/// Max-abs difference between two vectors.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{Dense, LocalInfo};

    fn two_part_matrix() -> (Vec<Csr>, Dense) {
        // 4x4 global matrix split into two row bands.
        let mut dense = Dense::zeros(4, 4);
        let entries = [
            (0usize, 0usize, 2.0),
            (0, 3, 1.0),
            (1, 1, -1.0),
            (2, 0, 4.0),
            (3, 2, 0.5),
        ];
        for &(i, j, v) in &entries {
            dense.set(i, j, v);
        }
        let mut parts = Vec::new();
        for (off, rows) in [(0u64, 0..2usize), (2, 2..4)] {
            let info = LocalInfo {
                m: 4,
                n: 4,
                z: 5,
                m_local: 2,
                n_local: 4,
                z_local: 0,
                m_offset: off,
                n_offset: 0,
            };
            let mut coo = Coo::with_info(info);
            for &(i, j, v) in &entries {
                if rows.contains(&i) {
                    coo.push(i as u64 - off, j as u64, v);
                }
            }
            parts.push(Csr::from_coo(&coo));
        }
        (parts, dense)
    }

    #[test]
    fn distributed_spmv_matches_dense() {
        let (parts, dense) = two_part_matrix();
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let y = SpmvParts::Csr(&parts).spmv(&x);
        assert_eq!(y, dense.matvec(&x));
    }

    #[test]
    fn coo_and_csr_agree() {
        let (parts, _) = two_part_matrix();
        let coo_parts: Vec<Coo> = parts.iter().map(|p| p.to_coo()).collect();
        let x = vec![0.5, -1.0, 2.0, 0.0];
        let y1 = SpmvParts::Csr(&parts).spmv(&x);
        let y2 = SpmvParts::Coo(&coo_parts).spmv(&x);
        assert!(max_abs_diff(&y1, &y2) < 1e-15);
    }

    #[test]
    fn power_iteration_normalizes() {
        let (parts, _) = two_part_matrix();
        let x = vec![1.0; 4];
        let (x2, norm) = power_iteration_step_parts(&SpmvParts::Csr(&parts), &x);
        assert!(norm > 0.0);
        let n2 = x2.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!((n2 - 1.0).abs() < 1e-12);
    }

    /// The `Elements` variant (the serving layer's cached-block shape)
    /// computes the same product and power step as the CSR parts.
    #[test]
    fn elements_parts_match_csr() {
        let (parts, dense) = two_part_matrix();
        let triplets: Vec<Vec<(u64, u64, f64)>> = parts
            .iter()
            .map(|p| {
                let coo = p.to_coo();
                let (ro, co) = (coo.info.m_offset, coo.info.n_offset);
                coo.iter().map(|(i, j, v)| (i + ro, j + co, v)).collect()
            })
            .collect();
        let slices: Vec<&[(u64, u64, f64)]> = triplets.iter().map(|t| t.as_slice()).collect();
        let elems = SpmvParts::Elements {
            m: 4,
            n: 4,
            parts: &slices,
        };
        assert_eq!(elems.rows(), 4);
        let x = vec![1.0, -2.0, 0.5, 3.0];
        assert!(max_abs_diff(&elems.spmv(&x), &dense.matvec(&x)) < 1e-12);
        let (xa, na) = power_iteration_step_parts(&SpmvParts::Csr(&parts), &x);
        let (xb, nb) = power_iteration_step_parts(&elems, &x);
        assert!((na - nb).abs() < 1e-12);
        assert!(max_abs_diff(&xa, &xb) < 1e-12);
    }

    #[test]
    fn zero_matrix_power_step() {
        let info = LocalInfo::whole(3, 3, 0);
        let parts = vec![Csr::from_coo(&Coo::with_info(info))];
        let (y, norm) =
            power_iteration_step_parts(&SpmvParts::Csr(&parts), &[1.0, 1.0, 1.0]);
        assert_eq!(norm, 0.0);
        assert_eq!(y, vec![0.0; 3]);
    }
}
