//! Minimal command-line argument parser (no `clap` in the vendored registry).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed accessors and automatic usage/error reporting. Sufficient for
//! the `abhsf` CLI's subcommand style: `abhsf <subcommand> [options]`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A declared option for usage text.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Option name without leading dashes.
    pub name: &'static str,
    /// Value placeholder (`None` for boolean flags).
    pub value: Option<&'static str>,
    /// Help text.
    pub help: &'static str,
    /// Default rendered in help, if any.
    pub default: Option<String>,
}

/// Parsed arguments: options map + positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    specs: Vec<OptSpec>,
    prog: String,
}

/// Parse error with message suitable for direct printing.
#[derive(Debug, thiserror::Error)]
#[error("{0}")]
pub struct ArgError(pub String);

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    ///
    /// `flag_names` lists options that take no value; everything else that
    /// starts with `--` is treated as `--key value` / `--key=value`.
    pub fn parse<I: IntoIterator<Item = String>>(
        prog: &str,
        raw: I,
        flag_names: &[&str],
    ) -> Result<Self, ArgError> {
        let mut args = Args {
            prog: prog.to_string(),
            ..Default::default()
        };
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest are positional.
                    args.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    args.flags.push(body.to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        ArgError(format!("option --{body} expects a value"))
                    })?;
                    args.opts.insert(body.to_string(), v);
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Register an option spec (for usage text only).
    pub fn spec(
        &mut self,
        name: &'static str,
        value: Option<&'static str>,
        help: &'static str,
        default: Option<String>,
    ) -> &mut Self {
        self.specs.push(OptSpec {
            name,
            value,
            help,
            default,
        });
        self
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Whether a boolean flag was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.get(name).is_some_and(|v| v == "true")
    }

    /// Raw string option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Typed option parse with default.
    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, ArgError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|e| ArgError(format!("invalid value for --{name}: {s:?} ({e})"))),
        }
    }

    /// Required typed option.
    pub fn require<T: std::str::FromStr>(&self, name: &str) -> Result<T, ArgError>
    where
        T::Err: std::fmt::Display,
    {
        let s = self
            .get(name)
            .ok_or_else(|| ArgError(format!("missing required option --{name}")))?;
        s.parse::<T>()
            .map_err(|e| ArgError(format!("invalid value for --{name}: {s:?} ({e})")))
    }

    /// Comma-separated list of typed values, with default on absence.
    pub fn list_or<T: std::str::FromStr>(
        &self,
        name: &str,
        default: &[T],
    ) -> Result<Vec<T>, ArgError>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| {
                    p.trim()
                        .parse::<T>()
                        .map_err(|e| ArgError(format!("invalid list item in --{name}: {p:?} ({e})")))
                })
                .collect(),
        }
    }

    /// Render usage text from registered specs.
    pub fn usage(&self, summary: &str) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{summary}\n\nUsage: {} [options]\n\nOptions:", self.prog);
        for s in &self.specs {
            let lhs = match s.value {
                Some(v) => format!("--{} <{v}>", s.name),
                None => format!("--{}", s.name),
            };
            let default = s
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(out, "  {:<28} {}{}", lhs, s.help, default);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_key_value_and_equals() {
        let a = Args::parse("t", v(&["--n", "10", "--path=/tmp/x", "pos1"]), &[]).unwrap();
        assert_eq!(a.get("n"), Some("10"));
        assert_eq!(a.get("path"), Some("/tmp/x"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn parses_flags() {
        let a = Args::parse("t", v(&["--verbose", "--n", "3"]), &["verbose"]).unwrap();
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.parse_or("n", 0u32).unwrap(), 3);
    }

    #[test]
    fn typed_parsing_and_errors() {
        let a = Args::parse("t", v(&["--n", "notanum"]), &[]).unwrap();
        assert!(a.parse_or("n", 1u32).is_err());
        assert!(a.require::<u32>("missing").is_err());
        assert_eq!(a.parse_or("absent", 7u64).unwrap(), 7);
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse("t", v(&["--ps", "1,2, 4,8"]), &[]).unwrap();
        assert_eq!(a.list_or::<u32>("ps", &[]).unwrap(), vec![1, 2, 4, 8]);
        assert_eq!(a.list_or::<u32>("qs", &[5]).unwrap(), vec![5]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse("t", v(&["--n"]), &[]).is_err());
    }

    #[test]
    fn double_dash_terminator() {
        let a = Args::parse("t", v(&["--", "--not-an-opt"]), &[]).unwrap();
        assert_eq!(a.positional(), &["--not-an-opt".to_string()]);
    }

    #[test]
    fn usage_renders() {
        let mut a = Args::parse("prog", v(&[]), &[]).unwrap();
        a.spec("n", Some("N"), "number of things", Some("4".into()));
        a.spec("verbose", None, "chatty output", None);
        let u = a.usage("Test tool.");
        assert!(u.contains("--n <N>"));
        assert!(u.contains("[default: 4]"));
    }
}
