//! Hand-rolled micro-benchmark harness.
//!
//! The vendored registry has no `criterion`, so `rust/benches/*` use this
//! module (`harness = false` in Cargo.toml). It provides warmup, adaptive
//! iteration counts, outlier-robust statistics, and aligned table output so
//! each bench binary can print the rows of the paper table/figure it
//! regenerates.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// One benchmark measurement: timing summary in seconds.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Label of the benchmark case.
    pub label: String,
    /// Per-iteration timing summary (seconds).
    pub summary: Summary,
    /// Optional throughput denominator (e.g. bytes or nnz processed per iter).
    pub throughput_items: Option<f64>,
}

impl Measurement {
    /// Mean time per iteration in seconds.
    pub fn mean_s(&self) -> f64 {
        self.summary.mean
    }

    /// items/s if a throughput denominator was set.
    pub fn throughput(&self) -> Option<f64> {
        self.throughput_items.map(|items| items / self.summary.mean)
    }
}

/// Bench runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Minimum wall time to spend measuring a case.
    pub min_time: Duration,
    /// Warmup time before measuring.
    pub warmup: Duration,
    /// Hard cap on measured iterations.
    pub max_iters: u64,
    /// Minimum measured iterations.
    pub min_iters: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            min_time: Duration::from_millis(600),
            warmup: Duration::from_millis(150),
            max_iters: 1000,
            min_iters: 5,
        }
    }
}

impl Bencher {
    /// Quick profile for expensive end-to-end cases.
    pub fn quick() -> Self {
        Self {
            min_time: Duration::from_millis(200),
            warmup: Duration::from_millis(50),
            max_iters: 50,
            min_iters: 3,
        }
    }

    /// Run `f` repeatedly, timing each call, until `min_time` has elapsed
    /// (at least `min_iters`, at most `max_iters` iterations).
    pub fn run<F: FnMut()>(&self, label: &str, mut f: F) -> Measurement {
        // Warmup.
        let wstart = Instant::now();
        while wstart.elapsed() < self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while (samples.len() < self.min_iters as usize)
            || (start.elapsed() < self.min_time && samples.len() < self.max_iters as usize)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        Measurement {
            label: label.to_string(),
            summary: Summary::of(&samples),
            throughput_items: None,
        }
    }

    /// Like [`run`], attaching a throughput denominator (items per iter).
    pub fn run_with_items<F: FnMut()>(&self, label: &str, items: f64, f: F) -> Measurement {
        let mut m = self.run(label, f);
        m.throughput_items = Some(items);
        m
    }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Format an items/s rate with SI prefixes.
pub fn fmt_rate(r: f64, unit: &str) -> String {
    if r >= 1e9 {
        format!("{:.2} G{unit}/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2} M{unit}/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2} k{unit}/s", r / 1e3)
    } else {
        format!("{:.1} {unit}/s", r)
    }
}

/// Simple fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells.to_vec());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cell, width = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_runs_minimum_iterations() {
        let b = Bencher {
            min_time: Duration::from_millis(1),
            warmup: Duration::from_millis(0),
            max_iters: 10,
            min_iters: 5,
        };
        let mut count = 0u64;
        let m = b.run("noop", || {
            count += 1;
        });
        assert!(m.summary.n >= 5);
        assert!(count >= 5);
    }

    #[test]
    fn throughput_computed() {
        let b = Bencher {
            min_time: Duration::from_millis(1),
            warmup: Duration::from_millis(0),
            max_iters: 8,
            min_iters: 3,
        };
        let m = b.run_with_items("items", 1000.0, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(m.throughput().unwrap() > 0.0);
    }

    #[test]
    fn fmt_helpers() {
        assert!(fmt_time(2.5e-9).ends_with("ns"));
        assert!(fmt_time(2.5e-6).ends_with("µs"));
        assert!(fmt_time(2.5e-3).ends_with("ms"));
        assert!(fmt_time(2.5).ends_with('s'));
        assert!(fmt_rate(5e9, "B").starts_with("5.00 G"));
        assert!(fmt_rate(5e3, "nnz").contains('k'));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["case", "time"]);
        t.row(&["a".into(), "1 ms".into()]);
        t.row(&["longer-name".into(), "2 ms".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("case"));
        assert!(lines[1].chars().all(|c| c == '-'));
    }
}
