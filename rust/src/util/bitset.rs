//! Compact bitset used by the ABHSF bitmap block scheme.
//!
//! Bit order matches the paper's Algorithm 5: bits are consumed from the
//! least significant bit of each byte upward, row-major over the block.

/// Growable bitset backed by bytes, LSB-first within each byte.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BitSet {
    bytes: Vec<u8>,
    len_bits: usize,
}

impl BitSet {
    /// Empty bitset.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bitset with `n` bits, all zero.
    pub fn zeros(n: usize) -> Self {
        Self {
            bytes: vec![0u8; n.div_ceil(8)],
            len_bits: n,
        }
    }

    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len_bits
    }

    /// True if no bits.
    pub fn is_empty(&self) -> bool {
        self.len_bits == 0
    }

    /// Append one bit.
    pub fn push(&mut self, bit: bool) {
        let idx = self.len_bits;
        if idx / 8 == self.bytes.len() {
            self.bytes.push(0);
        }
        if bit {
            self.bytes[idx / 8] |= 1 << (idx % 8);
        }
        self.len_bits += 1;
    }

    /// Get bit `i` (panics out of range).
    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.len_bits, "bit index {i} out of range {}", self.len_bits);
        (self.bytes[i / 8] >> (i % 8)) & 1 == 1
    }

    /// Set bit `i` to `v`.
    pub fn set(&mut self, i: usize, v: bool) {
        assert!(i < self.len_bits, "bit index {i} out of range {}", self.len_bits);
        if v {
            self.bytes[i / 8] |= 1 << (i % 8);
        } else {
            self.bytes[i / 8] &= !(1 << (i % 8));
        }
    }

    /// Count of set bits.
    pub fn count_ones(&self) -> usize {
        self.bytes.iter().map(|b| b.count_ones() as usize).sum()
    }

    /// Backing bytes (padded with zero bits to a byte boundary).
    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Construct from raw bytes and a bit length.
    pub fn from_bytes(bytes: Vec<u8>, len_bits: usize) -> Self {
        assert!(bytes.len() * 8 >= len_bits, "too few bytes for {len_bits} bits");
        Self { bytes, len_bits }
    }

    /// Iterator over bits.
    pub fn iter(&self) -> impl Iterator<Item = bool> + '_ {
        (0..self.len_bits).map(move |i| self.get(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_get_roundtrip() {
        let pattern = [true, false, false, true, true, true, false, true, true, false];
        let mut b = BitSet::new();
        for &bit in &pattern {
            b.push(bit);
        }
        assert_eq!(b.len(), pattern.len());
        for (i, &bit) in pattern.iter().enumerate() {
            assert_eq!(b.get(i), bit, "bit {i}");
        }
        assert_eq!(b.count_ones(), pattern.iter().filter(|&&x| x).count());
    }

    #[test]
    fn lsb_first_byte_layout() {
        let mut b = BitSet::new();
        // bits 0..8 = 1,0,0,0,0,0,0,1 -> byte 0b1000_0001
        for bit in [true, false, false, false, false, false, false, true] {
            b.push(bit);
        }
        assert_eq!(b.as_bytes(), &[0b1000_0001]);
    }

    #[test]
    fn zeros_set_get() {
        let mut b = BitSet::zeros(20);
        assert_eq!(b.count_ones(), 0);
        b.set(13, true);
        assert!(b.get(13));
        b.set(13, false);
        assert!(!b.get(13));
    }

    #[test]
    fn from_bytes_roundtrip() {
        let mut b = BitSet::new();
        for i in 0..23 {
            b.push(i % 3 == 0);
        }
        let b2 = BitSet::from_bytes(b.as_bytes().to_vec(), b.len());
        assert_eq!(b, b2);
    }

    #[test]
    fn iter_matches_get() {
        let mut b = BitSet::new();
        for i in 0..17 {
            b.push(i % 2 == 1);
        }
        let collected: Vec<bool> = b.iter().collect();
        for (i, &bit) in collected.iter().enumerate() {
            assert_eq!(bit, b.get(i));
        }
    }
}
