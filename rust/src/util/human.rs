//! Human-readable byte/count formatting.

/// Format a byte count with binary prefixes (KiB/MiB/GiB).
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if n < 1024 {
        return format!("{n} B");
    }
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// Format a large count with thousands separators.
pub fn count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let bytes = s.as_bytes();
    for (i, ch) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*ch as char);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formats() {
        assert_eq!(bytes(0), "0 B");
        assert_eq!(bytes(1023), "1023 B");
        assert_eq!(bytes(1024), "1.00 KiB");
        assert_eq!(bytes(1536), "1.50 KiB");
        assert_eq!(bytes(1024 * 1024), "1.00 MiB");
        assert_eq!(bytes(5 * 1024 * 1024 * 1024), "5.00 GiB");
    }

    #[test]
    fn count_separators() {
        assert_eq!(count(0), "0");
        assert_eq!(count(999), "999");
        assert_eq!(count(1000), "1,000");
        assert_eq!(count(1234567), "1,234,567");
        assert_eq!(count(12), "12");
    }
}
