//! Human-readable byte/count formatting.

/// Format a byte count with binary prefixes (KiB/MiB/GiB).
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if n < 1024 {
        return format!("{n} B");
    }
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// Format a byte count as the shortest spelling [`parse_bytes`] maps back
/// to *exactly* the same value — the lossless inverse (`1536` →
/// `"1.5KiB"`, `1 << 20` → `"1MiB"`), where [`bytes`] is the lossy
/// two-decimal display. Falls back to the plain decimal count whenever a
/// unit spelling would be long or inexact (fraction beyond 4 digits, or
/// values past 2^53 where `f64` stops being exact).
pub fn format_bytes(n: u64) -> String {
    const UNITS: [(&str, u32); 5] =
        [("PiB", 50), ("TiB", 40), ("GiB", 30), ("MiB", 20), ("KiB", 10)];
    if n >= (1u64 << 53) {
        return n.to_string();
    }
    for (unit, shift) in UNITS {
        let div = 1u64 << shift;
        if n >= div {
            // Exact: n < 2^53 is representable, and dividing by a power
            // of two only shifts the exponent. `{v}` prints the shortest
            // string that parses back to v.
            let v = n as f64 / div as f64;
            let s = format!("{v}");
            let short = match s.find('.') {
                Some(dot) => s.len() - dot - 1 <= 4,
                None => true,
            };
            return if short { format!("{s}{unit}") } else { n.to_string() };
        }
    }
    n.to_string()
}

/// Parse a human byte size: a plain number, or a (possibly fractional)
/// number with a binary suffix — `KiB`/`MiB`/`GiB`/`TiB`/`PiB`,
/// case-insensitive, with the `iB`/`B` tail optional and `KB`-style
/// spellings accepted as their binary meaning (`64K`, `1m`, `1.5GiB`,
/// `512kb` all parse). The inverse of [`format_bytes`] for CLI options
/// like `serve --budget 1MiB`.
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let t = s.trim();
    let split = t
        .find(|c: char| !(c.is_ascii_digit() || c == '.'))
        .unwrap_or(t.len());
    let (num, suffix) = t.split_at(split);
    let suffix = suffix.trim().to_ascii_lowercase();
    // Suffixless integers (and plain `B`) parse as u64 directly, staying
    // exact beyond 2^53 where the f64 path would round.
    if (suffix.is_empty() || suffix == "b") && !num.contains('.') {
        return num
            .parse()
            .map_err(|_| format!("unparsable byte count {s:?}"));
    }
    let value: f64 = num
        .parse()
        .map_err(|_| format!("unparsable byte count {s:?}"))?;
    let mult: f64 = match suffix.as_str() {
        "" | "b" => 1.0,
        "k" | "kib" | "kb" => 1024.0,
        "m" | "mib" | "mb" => 1024.0 * 1024.0,
        "g" | "gib" | "gb" => 1024.0 * 1024.0 * 1024.0,
        "t" | "tib" | "tb" => 1024.0 * 1024.0 * 1024.0 * 1024.0,
        "p" | "pib" | "pb" => 1024.0 * 1024.0 * 1024.0 * 1024.0 * 1024.0,
        other => return Err(format!("unknown byte suffix {other:?} in {s:?}")),
    };
    Ok((value * mult) as u64)
}

/// Format a large count with thousands separators.
pub fn count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let bytes = s.as_bytes();
    for (i, ch) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*ch as char);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formats() {
        assert_eq!(bytes(0), "0 B");
        assert_eq!(bytes(1023), "1023 B");
        assert_eq!(bytes(1024), "1.00 KiB");
        assert_eq!(bytes(1536), "1.50 KiB");
        assert_eq!(bytes(1024 * 1024), "1.00 MiB");
        assert_eq!(bytes(5 * 1024 * 1024 * 1024), "5.00 GiB");
    }

    #[test]
    fn parse_bytes_roundtrips_common_spellings() {
        assert_eq!(parse_bytes("0").unwrap(), 0);
        assert_eq!(parse_bytes("1234").unwrap(), 1234);
        assert_eq!(parse_bytes("1KiB").unwrap(), 1024);
        assert_eq!(parse_bytes("1MiB").unwrap(), 1 << 20);
        assert_eq!(parse_bytes("1mib").unwrap(), 1 << 20);
        assert_eq!(parse_bytes("64K").unwrap(), 64 * 1024);
        assert_eq!(parse_bytes("512kb").unwrap(), 512 * 1024);
        assert_eq!(parse_bytes("2GiB").unwrap(), 2u64 << 30);
        assert_eq!(parse_bytes("1TiB").unwrap(), 1u64 << 40);
        assert_eq!(parse_bytes(" 1.5 MiB ").unwrap(), 3 << 19);
        assert_eq!(parse_bytes("100B").unwrap(), 100);
        assert_eq!(parse_bytes("1.5GiB").unwrap(), 3u64 << 29);
        assert_eq!(parse_bytes("0.5k").unwrap(), 512);
        assert_eq!(parse_bytes("2PiB").unwrap(), 2u64 << 50);
        assert_eq!(parse_bytes("1pb").unwrap(), 1u64 << 50);
        // Suffixless integers stay exact even past 2^53.
        assert_eq!(parse_bytes("18446744073709551615").unwrap(), u64::MAX);
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("MiB").is_err());
        assert!(parse_bytes("10x").is_err());
        assert!(parse_bytes("-5").is_err());
    }

    #[test]
    fn format_bytes_picks_exact_spellings() {
        assert_eq!(format_bytes(0), "0");
        assert_eq!(format_bytes(1023), "1023");
        assert_eq!(format_bytes(1024), "1KiB");
        assert_eq!(format_bytes(1536), "1.5KiB");
        assert_eq!(format_bytes(1 << 20), "1MiB");
        assert_eq!(format_bytes(3 << 19), "1.5MiB");
        assert_eq!(format_bytes(5 << 30), "5GiB");
        assert_eq!(format_bytes(1 << 50), "1PiB");
        // A fraction longer than 4 digits falls back to plain decimal.
        assert_eq!(format_bytes(1025), "1025");
        assert_eq!(format_bytes((1 << 20) + 1), "1048577");
    }

    /// The satellite property: `parse_bytes(format_bytes(n)) == n` for
    /// every u64 — spot-checked over a seeded mix of raw values, unit
    /// multiples and small counts.
    #[test]
    fn format_bytes_roundtrips_through_parse_bytes() {
        use crate::util::rng::Xoshiro256;
        let mut rng = Xoshiro256::seed_from_u64(0x5eed);
        for i in 0..4000u64 {
            let n = match i % 4 {
                // Raw values across all magnitudes (incl. >= 2^53).
                0 => rng.next_u64() >> (rng.next_u64() % 64),
                // Exact unit multiples: the cases that format with a suffix.
                1 => (rng.next_u64() % (1 << 20)) << (10 * (rng.next_u64() % 6)),
                // Small counts.
                2 => rng.next_u64() % 4096,
                _ => rng.next_u64(),
            };
            let s = format_bytes(n);
            assert_eq!(parse_bytes(&s).unwrap(), n, "{n} -> {s:?}");
        }
        for n in [0, 1, 1023, 1024, 1025, u64::MAX, 1 << 53, (1 << 53) - 1] {
            assert_eq!(parse_bytes(&format_bytes(n)).unwrap(), n);
        }
    }

    #[test]
    fn count_separators() {
        assert_eq!(count(0), "0");
        assert_eq!(count(999), "999");
        assert_eq!(count(1000), "1,000");
        assert_eq!(count(1234567), "1,234,567");
        assert_eq!(count(12), "12");
    }
}
