//! Human-readable byte/count formatting.

/// Format a byte count with binary prefixes (KiB/MiB/GiB).
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if n < 1024 {
        return format!("{n} B");
    }
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// Parse a human byte size: a plain number, or a number with a binary
/// suffix — `KiB`/`MiB`/`GiB`/`TiB`, case-insensitive, with the `iB`/`B`
/// tail optional and `KB`-style spellings accepted as their binary
/// meaning (`64K`, `1m`, `2GiB`, `512kb` all parse). The inverse of
/// [`bytes`] for CLI options like `serve --budget 1MiB`.
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let t = s.trim();
    let split = t
        .find(|c: char| !(c.is_ascii_digit() || c == '.'))
        .unwrap_or(t.len());
    let (num, suffix) = t.split_at(split);
    let value: f64 = num
        .parse()
        .map_err(|_| format!("unparsable byte count {s:?}"))?;
    let mult: f64 = match suffix.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1.0,
        "k" | "kib" | "kb" => 1024.0,
        "m" | "mib" | "mb" => 1024.0 * 1024.0,
        "g" | "gib" | "gb" => 1024.0 * 1024.0 * 1024.0,
        "t" | "tib" | "tb" => 1024.0 * 1024.0 * 1024.0 * 1024.0,
        other => return Err(format!("unknown byte suffix {other:?} in {s:?}")),
    };
    Ok((value * mult) as u64)
}

/// Format a large count with thousands separators.
pub fn count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let bytes = s.as_bytes();
    for (i, ch) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*ch as char);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formats() {
        assert_eq!(bytes(0), "0 B");
        assert_eq!(bytes(1023), "1023 B");
        assert_eq!(bytes(1024), "1.00 KiB");
        assert_eq!(bytes(1536), "1.50 KiB");
        assert_eq!(bytes(1024 * 1024), "1.00 MiB");
        assert_eq!(bytes(5 * 1024 * 1024 * 1024), "5.00 GiB");
    }

    #[test]
    fn parse_bytes_roundtrips_common_spellings() {
        assert_eq!(parse_bytes("0").unwrap(), 0);
        assert_eq!(parse_bytes("1234").unwrap(), 1234);
        assert_eq!(parse_bytes("1KiB").unwrap(), 1024);
        assert_eq!(parse_bytes("1MiB").unwrap(), 1 << 20);
        assert_eq!(parse_bytes("1mib").unwrap(), 1 << 20);
        assert_eq!(parse_bytes("64K").unwrap(), 64 * 1024);
        assert_eq!(parse_bytes("512kb").unwrap(), 512 * 1024);
        assert_eq!(parse_bytes("2GiB").unwrap(), 2u64 << 30);
        assert_eq!(parse_bytes("1TiB").unwrap(), 1u64 << 40);
        assert_eq!(parse_bytes(" 1.5 MiB ").unwrap(), 3 << 19);
        assert_eq!(parse_bytes("100B").unwrap(), 100);
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("MiB").is_err());
        assert!(parse_bytes("10x").is_err());
        assert!(parse_bytes("-5").is_err());
    }

    #[test]
    fn count_separators() {
        assert_eq!(count(0), "0");
        assert_eq!(count(999), "999");
        assert_eq!(count(1000), "1,000");
        assert_eq!(count(1234567), "1,234,567");
        assert_eq!(count(12), "12");
    }
}
