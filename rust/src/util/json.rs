//! Minimal JSON parser and serializer (no `serde` in the vendored
//! registry).
//!
//! Parses the artifact `manifest.json` written by `python/compile/aot.py`
//! and round-trips the dataset manifest (`dataset.json`) written by
//! [`crate::coordinator::Dataset`]. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP; numbers parse as f64, so exact
//! integers are limited to ±2^53 (far beyond any matrix dimension or file
//! size this crate handles).

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true / false
    Bool(bool),
    /// Any number (f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (ordered by key).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = P {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer value (exact f64 only).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// Array items.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Build a number from an unsigned integer (exact up to 2^53).
    pub fn num(v: u64) -> Json {
        Json::Num(v as f64)
    }

    /// Build a string value.
    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    /// Build an array from u64s (the common manifest case).
    pub fn arr_u64(vs: &[u64]) -> Json {
        Json::Arr(vs.iter().map(|&v| Json::num(v)).collect())
    }
}

impl std::fmt::Display for Json {
    /// Serialize to compact JSON. Integers within ±2^53 print without a
    /// fractional part so `parse(to_string(v)) == v` for manifest data.
    /// Non-finite numbers (JSON cannot represent them) serialize as
    /// `null`, matching the common lossy convention.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => f.write_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    f.write_str("null")
                } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{item}")?;
                }
                f.write_str("]")
            }
            Json::Obj(map) => {
                f.write_str("{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut std::fmt::Formatter<'_>, s: &str) -> std::fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

struct P<'a> {
    b: &'a [u8],
    i: usize,
}

impl P<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("truncated escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                Some(_) => {
                    // Copy a UTF-8 run.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid utf8 in string")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] at byte {}, got {other:?}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} at byte {}, got {other:?}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": "x"}], "c": {"d": null}, "e": false}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c").unwrap().get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_manifest_like() {
        let doc = r#"{
          "format": "hlo-text",
          "artifacts": [
            {"name": "spmv", "file": "spmv.hlo.txt",
             "inputs": [{"name": "x", "dtype": "f32", "shape": [64]}],
             "params": {"r": 32, "k": 8}}
          ]
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("params").unwrap().get("r").unwrap().as_u64(), Some(32));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_u64(), Some(64));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".to_string())
        );
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn serializer_roundtrips() {
        let docs = [
            r#"{"a": [1, 2, {"b": "x"}], "c": {"d": null}, "e": false}"#,
            r#"{"name": "q\"uo\\te", "nl": "a\nb", "big": 9007199254740992}"#,
            "[-1.5, 0.25, 1e300]",
            "[]",
            "{}",
        ];
        for doc in docs {
            let v = Json::parse(doc).unwrap();
            let text = v.to_string();
            assert_eq!(Json::parse(&text).unwrap(), v, "roundtrip of {doc}");
        }
    }

    #[test]
    fn serializer_emits_null_for_non_finite() {
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        // Overflowing literals parse to inf; the serialization must
        // still be valid JSON.
        let v = Json::parse("[1e999]").unwrap();
        assert!(Json::parse(&v.to_string()).is_ok(), "{v}");
    }

    #[test]
    fn serializer_integers_stay_integers() {
        let mut obj = BTreeMap::new();
        obj.insert("bytes".to_string(), Json::num(123_456_789_012));
        obj.insert("starts".to_string(), Json::arr_u64(&[0, 5, 10]));
        let text = Json::Obj(obj).to_string();
        assert_eq!(text, r#"{"bytes":123456789012,"starts":[0,5,10]}"#);
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("bytes").unwrap().as_u64(), Some(123_456_789_012));
    }
}
