//! Shared utilities: deterministic RNG, statistics, bench harness, CLI args,
//! bitsets, and human-readable formatting.

pub mod args;
pub mod bench;
pub mod bitset;
pub mod human;
pub mod json;
pub mod rng;
pub mod stats;
