//! Deterministic pseudo-random number generation.
//!
//! The offline vendored registry has no `rand` crate, so we carry our own
//! small, well-known generators: [`SplitMix64`] for seeding and
//! [`Xoshiro256`] (xoshiro256**) as the workhorse. Determinism matters more
//! than statistical perfection here: matrix generation, property-style tests
//! and workload sweeps must be exactly reproducible from a `u64` seed.

/// SplitMix64 — used to expand a single `u64` seed into a full
/// xoshiro256** state. Passes BigCrush; never yields all-zero output.
#[derive(Debug, Clone, Copy)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast all-purpose generator with 256-bit state.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference implementation's guidance.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u64 in `[0, bound)` via Lemire's multiply-shift with
    /// rejection; `bound` must be nonzero.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "next_below(0)");
        // Unbiased widening-multiply rejection sampling.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// f64 uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        if k * 4 >= n {
            // Dense case: shuffle a full index vector.
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // Sparse case: rejection with a sorted probe set.
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let x = self.next_below(n as u64) as usize;
                if seen.insert(x) {
                    out.push(x);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference vector for seed 1234567 from the public-domain impl.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_seeds() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        let mut c = Xoshiro256::seed_from_u64(43);
        let va: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Xoshiro256::seed_from_u64(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn next_f64_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(99);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256::seed_from_u64(11);
        for &(n, k) in &[(10usize, 10usize), (1000, 5), (50, 25)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }
}
