//! Small statistics helpers for the bench harness and metrics.

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Number of observations.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator; 0 for n < 2).
    pub std_dev: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Median (linear interpolation).
    pub median: f64,
    /// 95th percentile (linear interpolation).
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }

    /// Relative standard deviation (coefficient of variation); 0 if mean==0.
    pub fn rsd(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std_dev / self.mean
        }
    }
}

/// Percentile with linear interpolation over an already sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&pct));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Online mean/min/max/count accumulator (Welford) for streaming metrics.
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one observation in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observation count.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean (0 for empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample standard deviation.
    pub fn std_dev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Minimum (NaN for empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Maximum (NaN for empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Merge another accumulator (parallel reduction).
    pub fn merge(&mut self, other: &Accumulator) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std_dev - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.median - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.5]);
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.median, 7.5);
        assert_eq!(s.p95, 7.5);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 95.0) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn accumulator_matches_summary() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut acc = Accumulator::new();
        for &x in &xs {
            acc.push(x);
        }
        let s = Summary::of(&xs);
        assert_eq!(acc.count() as usize, s.n);
        assert!((acc.mean() - s.mean).abs() < 1e-12);
        assert!((acc.std_dev() - s.std_dev).abs() < 1e-12);
        assert_eq!(acc.min(), s.min);
        assert_eq!(acc.max(), s.max);
    }

    #[test]
    fn accumulator_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Accumulator::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Accumulator::new();
        let mut b = Accumulator::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.std_dev() - whole.std_dev()).abs() < 1e-9);
    }
}
