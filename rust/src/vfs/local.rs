//! [`LocalFs`] — the real filesystem backend (`std::fs`), the default.

use std::fs::{File, OpenOptions};
use std::io::{self, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::vfs::{normalize, Storage, StorageRead, StorageWrite};

/// The real filesystem. Stateless: every instance sees the same files, so
/// all instances share one [`Storage::medium`].
#[derive(Debug, Clone, Copy, Default)]
pub struct LocalFs;

/// Read handle: a shared [`File`] behind a mutex. Positioned reads seek
/// then read under the lock, which keeps the handle `Sync` without
/// platform-specific `pread` extensions; the lock is uncontended except
/// when the read-ahead pipeline and a decoder race, and the pipeline owns
/// all reads while it runs.
struct LocalFile {
    file: Mutex<File>,
    len: u64,
}

impl StorageRead for LocalFile {
    fn read_exact_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        let mut f = self.file.lock().expect("local file lock poisoned");
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(buf)
    }

    fn len(&self) -> io::Result<u64> {
        Ok(self.len)
    }
}

/// Write handle: buffered appends, flush-then-seek patching, fsync.
struct LocalWriter {
    file: BufWriter<File>,
    pos: u64,
}

impl StorageWrite for LocalWriter {
    fn append(&mut self, buf: &[u8]) -> io::Result<()> {
        self.file.write_all(buf)?;
        self.pos += buf.len() as u64;
        Ok(())
    }

    fn patch_at(&mut self, offset: u64, buf: &[u8]) -> io::Result<()> {
        if offset + buf.len() as u64 > self.pos {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "patch_at beyond written bytes",
            ));
        }
        self.file.flush()?;
        let f = self.file.get_mut();
        f.seek(SeekFrom::Start(offset))?;
        f.write_all(buf)?;
        // Restore the append position for the (unsupported but cheap to
        // keep correct) case of further appends.
        f.seek(SeekFrom::Start(self.pos))?;
        Ok(())
    }

    fn sync(&mut self) -> io::Result<()> {
        self.file.flush()?;
        self.file.get_mut().sync_all()
    }
}

impl Storage for LocalFs {
    fn open(&self, path: &Path) -> io::Result<Arc<dyn StorageRead>> {
        let file = File::open(path)?;
        let len = file.metadata()?.len();
        Ok(Arc::new(LocalFile {
            file: Mutex::new(file),
            len,
        }))
    }

    fn create(&self, path: &Path) -> io::Result<Box<dyn StorageWrite>> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        Ok(Box::new(LocalWriter {
            file: BufWriter::new(file),
            pos: 0,
        }))
    }

    fn len(&self, path: &Path) -> io::Result<u64> {
        Ok(std::fs::metadata(path)?.len())
    }

    fn list(&self, dir: &Path) -> io::Result<Vec<PathBuf>> {
        let mut out: Vec<PathBuf> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .filter(|e| e.file_type().map(|t| t.is_file()).unwrap_or(false))
            .map(|e| e.path())
            .collect();
        out.sort();
        Ok(out)
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        std::fs::rename(from, to)
    }

    fn read_file(&self, path: &Path) -> io::Result<Vec<u8>> {
        std::fs::read(path)
    }

    fn write_file(&self, path: &Path, bytes: &[u8]) -> io::Result<()> {
        // Atomic publish: write a sibling temp file, then rename over the
        // destination — a failed write never leaves a partial file.
        let tmp = path.with_extension("tmp-write");
        std::fs::write(&tmp, bytes)?;
        std::fs::rename(&tmp, path)
    }

    fn create_dir_all(&self, dir: &Path) -> io::Result<()> {
        std::fs::create_dir_all(dir)
    }

    fn canonical(&self, path: &Path) -> PathBuf {
        std::fs::canonicalize(path).unwrap_or_else(|_| normalize(path))
    }

    fn medium(&self) -> usize {
        0
    }

    fn label(&self) -> &'static str {
        "local"
    }
}
