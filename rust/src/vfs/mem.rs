//! [`MemFs`] — an in-memory backend: a path → bytes map shared across
//! clones, so the cluster's worker threads all see one namespace.

use std::collections::BTreeMap;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};

use crate::vfs::{normalize, Storage, StorageRead, StorageWrite};

type FileMap = BTreeMap<PathBuf, Arc<Vec<u8>>>;

/// In-memory file namespace. `Clone` shares the underlying map (the
/// worker threads of a [`crate::coordinator::Cluster`] each hold a clone
/// and observe each other's writes); `MemFs::new` creates an independent
/// one. Paths are normalized lexically, so `a/b/../c` and `a/c` are the
/// same file; directories are implicit (any prefix exists).
#[derive(Clone, Default)]
pub struct MemFs {
    files: Arc<RwLock<FileMap>>,
}

impl std::fmt::Debug for MemFs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let files = self.files.read().expect("memfs lock poisoned");
        write!(f, "MemFs({} files)", files.len())
    }
}

impl MemFs {
    /// A fresh, empty namespace.
    pub fn new() -> Self {
        Self::default()
    }

    /// Total bytes held across all files (tests and reports).
    pub fn total_bytes(&self) -> u64 {
        let files = self.files.read().expect("memfs lock poisoned");
        files.values().map(|v| v.len() as u64).sum()
    }

    fn not_found(path: &Path) -> io::Error {
        io::Error::new(
            io::ErrorKind::NotFound,
            format!("no such file in MemFs: {}", path.display()),
        )
    }
}

/// Read handle: an immutable snapshot of the file's bytes at open time
/// (like an open POSIX fd surviving a concurrent replace).
struct MemFile {
    data: Arc<Vec<u8>>,
}

impl StorageRead for MemFile {
    fn read_exact_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        let end = offset + buf.len() as u64;
        if end > self.data.len() as u64 {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!(
                    "read [{offset}, {end}) past end of {}-byte in-memory file",
                    self.data.len()
                ),
            ));
        }
        buf.copy_from_slice(&self.data[offset as usize..end as usize]);
        Ok(())
    }

    fn len(&self) -> io::Result<u64> {
        Ok(self.data.len() as u64)
    }
}

/// Write handle: buffers locally and publishes into the shared map on
/// [`StorageWrite::sync`] and on drop — dropping an unfinished writer
/// leaves the partial bytes visible, exactly like an unflushed file on a
/// real filesystem (the h5spm "unfinished file" detection depends on it).
struct MemWriter {
    files: Arc<RwLock<FileMap>>,
    path: PathBuf,
    buf: Vec<u8>,
    /// Bytes appended since the last publish. Cleared on publish so a
    /// drop after a clean [`StorageWrite::sync`] is free (no second full
    /// copy of the file's bytes).
    dirty: bool,
}

impl MemWriter {
    fn publish(&mut self, bytes: Vec<u8>) {
        let mut files = self.files.write().expect("memfs lock poisoned");
        files.insert(self.path.clone(), Arc::new(bytes));
        self.dirty = false;
    }
}

impl StorageWrite for MemWriter {
    fn append(&mut self, buf: &[u8]) -> io::Result<()> {
        self.buf.extend_from_slice(buf);
        self.dirty = true;
        Ok(())
    }

    fn patch_at(&mut self, offset: u64, buf: &[u8]) -> io::Result<()> {
        let end = offset as usize + buf.len();
        if end > self.buf.len() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "patch_at beyond written bytes",
            ));
        }
        self.buf[offset as usize..end].copy_from_slice(buf);
        self.dirty = true;
        Ok(())
    }

    fn sync(&mut self) -> io::Result<()> {
        // The buffer must stay usable for post-sync appends, so sync
        // pays one copy; the (usual) drop right after is then free.
        let bytes = self.buf.clone();
        self.publish(bytes);
        Ok(())
    }
}

impl Drop for MemWriter {
    fn drop(&mut self) {
        if self.dirty {
            let bytes = std::mem::take(&mut self.buf);
            self.publish(bytes);
        }
    }
}

impl Storage for MemFs {
    fn open(&self, path: &Path) -> io::Result<Arc<dyn StorageRead>> {
        let path = normalize(path);
        let files = self.files.read().expect("memfs lock poisoned");
        let data = files.get(&path).ok_or_else(|| Self::not_found(&path))?;
        Ok(Arc::new(MemFile {
            data: Arc::clone(data),
        }))
    }

    fn create(&self, path: &Path) -> io::Result<Box<dyn StorageWrite>> {
        // A create *is* a truncation: publish the empty file immediately
        // so a never-written handle still leaves the truncated state,
        // like O_TRUNC does.
        let mut w = MemWriter {
            files: Arc::clone(&self.files),
            path: normalize(path),
            buf: Vec::new(),
            dirty: false,
        };
        w.publish(Vec::new());
        Ok(Box::new(w))
    }

    fn len(&self, path: &Path) -> io::Result<u64> {
        let path = normalize(path);
        let files = self.files.read().expect("memfs lock poisoned");
        files
            .get(&path)
            .map(|d| d.len() as u64)
            .ok_or_else(|| Self::not_found(&path))
    }

    fn list(&self, dir: &Path) -> io::Result<Vec<PathBuf>> {
        let dir = normalize(dir);
        let files = self.files.read().expect("memfs lock poisoned");
        Ok(files
            .keys()
            .filter(|p| p.parent() == Some(dir.as_path()))
            .cloned()
            .collect())
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        let (from, to) = (normalize(from), normalize(to));
        let mut files = self.files.write().expect("memfs lock poisoned");
        let data = files.remove(&from).ok_or_else(|| Self::not_found(&from))?;
        files.insert(to, data);
        Ok(())
    }

    fn read_file(&self, path: &Path) -> io::Result<Vec<u8>> {
        let path = normalize(path);
        let files = self.files.read().expect("memfs lock poisoned");
        files
            .get(&path)
            .map(|d| d.as_ref().clone())
            .ok_or_else(|| Self::not_found(&path))
    }

    fn write_file(&self, path: &Path, bytes: &[u8]) -> io::Result<()> {
        let mut files = self.files.write().expect("memfs lock poisoned");
        files.insert(normalize(path), Arc::new(bytes.to_vec()));
        Ok(())
    }

    fn create_dir_all(&self, _dir: &Path) -> io::Result<()> {
        // Directories are implicit.
        Ok(())
    }

    fn canonical(&self, path: &Path) -> PathBuf {
        normalize(path)
    }

    fn medium(&self) -> usize {
        Arc::as_ptr(&self.files) as usize
    }

    fn label(&self) -> &'static str {
        "mem"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clones_share_the_namespace() {
        let a = MemFs::new();
        let b = a.clone();
        a.write_file(Path::new("/x/f"), b"abc").unwrap();
        assert_eq!(b.read_file(Path::new("/x/f")).unwrap(), b"abc");
        assert_eq!(b.total_bytes(), 3);
        // Lexical aliasing: same file through a noisy path.
        assert_eq!(b.read_file(Path::new("/x/y/../f")).unwrap(), b"abc");
    }

    #[test]
    fn open_snapshots_survive_replacement() {
        let fs = MemFs::new();
        fs.write_file(Path::new("/f"), b"old!").unwrap();
        let r = fs.open(Path::new("/f")).unwrap();
        fs.write_file(Path::new("/f"), b"new").unwrap();
        let mut buf = [0u8; 4];
        r.read_exact_at(0, &mut buf).unwrap();
        assert_eq!(&buf, b"old!", "open handle must keep its snapshot");
    }

    #[test]
    fn dropped_writer_publishes_partial_bytes() {
        let fs = MemFs::new();
        {
            let mut w = fs.create(Path::new("/partial")).unwrap();
            w.append(b"half").unwrap();
            // Dropped without sync.
        }
        assert_eq!(fs.read_file(Path::new("/partial")).unwrap(), b"half");
    }
}
