//! Storage virtualization — pluggable backends behind every byte of
//! container I/O.
//!
//! The paper defines its loading algorithms against an abstract parallel
//! file system; this module is that abstraction made executable. A
//! [`Storage`] implementation owns a namespace of files and hands out
//! positioned read handles ([`StorageRead`]) and append-oriented write
//! handles ([`StorageWrite`]). Everything above the container layer —
//! [`crate::h5`], the [`crate::coordinator`] store/load orchestration,
//! [`crate::repack`] — is written against these traits, so the same
//! store/load/repack code runs over:
//!
//! * [`LocalFs`] — the real filesystem (`std::fs`), the default and the
//!   pre-virtualization behavior;
//! * [`MemFs`] — an in-memory file map shared (`Arc`) across the cluster
//!   worker threads: exact same bytes, no disk, used to run the
//!   differential harness an order of magnitude faster;
//! * [`SimFs`] — a decorator over any backend that *accounts* (and
//!   optionally sleeps) the [`crate::parfs::FsModel`] latency/bandwidth
//!   costs of every operation and injects storage faults (missing files,
//!   truncated reads, failed writes) so error paths are testable without
//!   hand-corrupting files on disk;
//! * [`RemoteFs`] — a TCP client to the `pallas-served` storage daemon
//!   ([`crate::net`]): the same trait surface spoken over a wire protocol
//!   with retries, backoff and typed error frames, so store/load/repack
//!   run against a dataset that lives on another machine.
//!
//! See DESIGN.md §9 for the trait contract and the backend matrix, and
//! §11 for the network tier.

pub mod local;
pub mod mem;
pub mod sim;

pub use local::LocalFs;
pub use mem::MemFs;
pub use sim::{FaultSpec, SimFs};

pub use crate::net::RemoteFs;

use std::io;
use std::path::{Component, Path, PathBuf};
use std::sync::Arc;

/// Positioned read handle to one stored file.
///
/// Handles are stateless (`read_exact_at` takes an explicit offset and a
/// `&self` receiver) and `Send + Sync`, so the read-ahead pipeline can
/// fetch from a background thread through the *same* handle the decoder
/// holds — no extra `open` is charged to the I/O trace.
pub trait StorageRead: Send + Sync {
    /// Fill `buf` from the bytes at `offset`, erroring (like
    /// [`std::io::Read::read_exact`]) if the file ends first.
    fn read_exact_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()>;

    /// Current file length in bytes.
    fn len(&self) -> io::Result<u64>;

    /// Whether the file has zero bytes.
    fn is_empty(&self) -> io::Result<bool> {
        Ok(self.len()? == 0)
    }
}

/// Append-oriented write handle to one file being created.
///
/// The h5spm writer streams chunks forward and patches the superblock
/// once at [`StorageWrite::sync`] time; the trait mirrors exactly that
/// life cycle: append, optionally patch already-written bytes, sync.
/// Appending after a patch is not part of the contract.
pub trait StorageWrite: Send {
    /// Append `buf` at the current end of the file.
    fn append(&mut self, buf: &[u8]) -> io::Result<()>;

    /// Overwrite already-written bytes at `offset` (superblock patching).
    fn patch_at(&mut self, offset: u64, buf: &[u8]) -> io::Result<()>;

    /// Flush and durably persist everything written so far.
    fn sync(&mut self) -> io::Result<()>;
}

/// A storage backend: a named-file namespace with open/create/metadata
/// operations. All methods take `&self`; implementations are shared
/// across cluster worker threads behind an `Arc<dyn Storage>`.
pub trait Storage: Send + Sync + std::fmt::Debug {
    /// Open an existing file for positioned reads.
    fn open(&self, path: &Path) -> io::Result<Arc<dyn StorageRead>>;

    /// Create (truncate) a file for appending.
    fn create(&self, path: &Path) -> io::Result<Box<dyn StorageWrite>>;

    /// Length of an existing file in bytes (the `stat` of this API).
    fn len(&self, path: &Path) -> io::Result<u64>;

    /// File paths directly inside `dir`, sorted.
    fn list(&self, dir: &Path) -> io::Result<Vec<PathBuf>>;

    /// Atomically rename `from` to `to` (same backend).
    fn rename(&self, from: &Path, to: &Path) -> io::Result<()>;

    /// Read a whole small file (manifests).
    fn read_file(&self, path: &Path) -> io::Result<Vec<u8>>;

    /// Write a whole small file **atomically**: on error, no partial file
    /// may remain at `path` (the manifest-write contract — a dataset
    /// directory either has a complete `dataset.json` or none).
    fn write_file(&self, path: &Path, bytes: &[u8]) -> io::Result<()>;

    /// Create a directory and its ancestors (no-op where the backend has
    /// no directory objects).
    fn create_dir_all(&self, dir: &Path) -> io::Result<()>;

    /// Canonical identity of `path` within this backend, for same-file /
    /// same-directory checks (e.g. refusing to repack a dataset into its
    /// own source directory). Purely lexical backends normalize; `LocalFs`
    /// resolves symlinks when the path exists.
    fn canonical(&self, path: &Path) -> PathBuf;

    /// Identity of the backing medium: two `Storage` values with the same
    /// medium see the same files. `LocalFs` instances all share medium 0
    /// (one real filesystem); each `MemFs` map is its own medium; [`SimFs`]
    /// reports its inner backend's.
    fn medium(&self) -> usize;

    /// Short label for reports and CLI output (`"local"`, `"mem"`, `"sim"`).
    fn label(&self) -> &'static str;
}

/// The default backend: the real filesystem.
pub fn local() -> Arc<dyn Storage> {
    Arc::new(LocalFs)
}

/// Lexical path normalization: resolve `.`/`..` components without
/// touching any filesystem. Shared by [`MemFs`] (which has no inodes) and
/// by [`LocalFs::canonical`] as the fallback for paths that do not exist
/// yet.
pub(crate) fn normalize(path: &Path) -> PathBuf {
    let mut out = PathBuf::new();
    for c in path.components() {
        match c {
            Component::CurDir => {}
            Component::ParentDir => {
                if !out.pop() {
                    out.push(Component::ParentDir);
                }
            }
            other => out.push(other),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every backend satisfies the same observable contract; the matrix
    /// below runs each backend through one create/read/metadata cycle.
    fn exercise(storage: &dyn Storage) {
        let dir = std::env::temp_dir().join(format!(
            "abhsf-vfs-contract-{}-{}",
            storage.label(),
            std::process::id()
        ));
        storage.create_dir_all(&dir).unwrap();
        let path = dir.join("file.bin");

        // Create: append, patch, sync.
        let mut w = storage.create(&path).unwrap();
        w.append(b"0123456789").unwrap();
        w.append(b"abcdef").unwrap();
        w.patch_at(2, b"XY").unwrap();
        w.sync().unwrap();
        drop(w);

        assert_eq!(storage.len(&path).unwrap(), 16);
        let r = storage.open(&path).unwrap();
        assert_eq!(r.len().unwrap(), 16);
        let mut buf = [0u8; 4];
        r.read_exact_at(0, &mut buf).unwrap();
        assert_eq!(&buf, b"01XY");
        r.read_exact_at(12, &mut buf).unwrap();
        assert_eq!(&buf, b"cdef");
        // Reading past the end errors instead of short-reading.
        assert!(r.read_exact_at(14, &mut buf).is_err());

        // Whole-file helpers + rename + list.
        storage.write_file(&dir.join("note.txt"), b"hello").unwrap();
        assert_eq!(storage.read_file(&dir.join("note.txt")).unwrap(), b"hello");
        storage
            .rename(&dir.join("note.txt"), &dir.join("note2.txt"))
            .unwrap();
        assert!(storage.read_file(&dir.join("note.txt")).is_err());
        let listed = storage.list(&dir).unwrap();
        assert!(listed.iter().any(|p| p.ends_with("file.bin")), "{listed:?}");
        assert!(listed.iter().any(|p| p.ends_with("note2.txt")), "{listed:?}");

        // Missing files are NotFound, not panics.
        assert!(storage.open(&dir.join("absent")).is_err());
        assert!(storage.len(&dir.join("absent")).is_err());

        // Canonical identity is stable under lexical noise.
        let noisy = dir.join("sub").join("..").join("file.bin");
        assert_eq!(storage.canonical(&noisy), storage.canonical(&path));
    }

    #[test]
    fn local_fs_contract() {
        exercise(&LocalFs);
    }

    #[test]
    fn mem_fs_contract() {
        exercise(&MemFs::new());
    }

    #[test]
    fn sim_fs_contract() {
        // A fault-free SimFs is behaviorally transparent.
        let sim = SimFs::new(
            Arc::new(MemFs::new()),
            crate::parfs::FsModel::local_nvme(),
        );
        exercise(&sim);
        assert!(sim.simulated_seconds() > 0.0, "no cost accounted");
    }

    #[test]
    fn normalize_is_lexical() {
        assert_eq!(
            normalize(Path::new("/a/b/../c/./d")),
            PathBuf::from("/a/c/d")
        );
        assert_eq!(normalize(Path::new("a/../../b")), PathBuf::from("../b"));
    }

    #[test]
    fn media_identities() {
        let a = MemFs::new();
        let b = a.clone();
        let c = MemFs::new();
        assert_eq!(a.medium(), b.medium(), "clones share the map");
        assert_ne!(a.medium(), c.medium(), "fresh maps are distinct");
        assert_eq!(LocalFs.medium(), LocalFs.medium());
        let sim = SimFs::new(Arc::new(a.clone()), crate::parfs::FsModel::local_nvme());
        assert_eq!(sim.medium(), a.medium(), "sim is transparent to identity");
    }
}
