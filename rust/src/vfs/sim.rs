//! [`SimFs`] — a decorator backend that prices every operation with the
//! [`crate::parfs::FsModel`] constants and injects storage faults.
//!
//! Two independent jobs, both impossible against the raw backends:
//!
//! * **Cost emulation.** Every open/read/write charges the parfs model's
//!   latency and per-client bandwidth terms to a simulated clock
//!   ([`SimFs::simulated_seconds`]); with a nonzero
//!   [`SimFs::time_scale`], the charge is also *slept*, turning the model
//!   from a prediction into an emulation the wall clock can observe.
//! * **Fault injection.** A [`FaultSpec`] makes files matching a
//!   substring disappear ([`FaultSpec::missing`]), appear truncated to
//!   half their length ([`FaultSpec::truncate`]), or reject writes
//!   ([`FaultSpec::fail_writes`]) — the three storage failure classes the
//!   dataset layer must surface as typed errors instead of panics.

use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::parfs::FsModel;
use crate::vfs::{Storage, StorageRead, StorageWrite};

/// Which operations fail, selected by a substring of the path. `None`
/// disables that fault class.
#[derive(Debug, Clone, Default)]
pub struct FaultSpec {
    /// Files whose path contains this substring do not exist: `open`,
    /// `len` and `read_file` return `NotFound`.
    pub missing: Option<String>,
    /// Files whose path contains this substring appear truncated to half
    /// their real length: reads past the cut fail with `UnexpectedEof`.
    pub truncate: Option<String>,
    /// Writes to paths containing this substring fail (`create` and
    /// `write_file` return `PermissionDenied`); nothing partial is left.
    pub fail_writes: Option<String>,
}

impl FaultSpec {
    /// Parse a CLI fault list: comma-separated `kind:substring` entries
    /// with kinds `missing`, `truncate` and `fail-writes`, e.g.
    /// `missing:matrix-1,truncate:matrix-0`.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut out = FaultSpec::default();
        for entry in spec.split(',').filter(|e| !e.trim().is_empty()) {
            let (kind, pat) = entry
                .split_once(':')
                .ok_or_else(|| format!("fault entry {entry:?} is not kind:substring"))?;
            // Trim the pattern too: `kind: pattern` with a space would
            // otherwise never match anything and the fault would be a
            // silent no-op. An empty pattern would match *every* path —
            // reject it rather than guess.
            let pat = pat.trim();
            if pat.is_empty() {
                return Err(format!("fault entry {entry:?} has an empty path substring"));
            }
            let pat = Some(pat.to_string());
            match kind.trim() {
                "missing" => out.missing = pat,
                "truncate" => out.truncate = pat,
                "fail-writes" => out.fail_writes = pat,
                other => {
                    return Err(format!(
                        "unknown fault kind {other:?} (missing|truncate|fail-writes)"
                    ))
                }
            }
        }
        Ok(out)
    }

    fn matches(pattern: &Option<String>, path: &Path) -> bool {
        pattern
            .as_deref()
            .is_some_and(|pat| path.to_string_lossy().contains(pat))
    }
}

/// Shared simulated-cost state: model constants, the accumulated clock,
/// and the sleep scale.
struct SimState {
    model: FsModel,
    clock_ns: AtomicU64,
    scale: f64,
}

impl SimState {
    /// Account `cost_s` of simulated time, sleeping `cost_s * scale`.
    fn charge(&self, cost_s: f64) {
        self.clock_ns
            .fetch_add((cost_s * 1e9) as u64, Ordering::Relaxed);
        if self.scale > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(cost_s * self.scale));
        }
    }

    fn charge_bytes(&self, op_lat: bool, bytes: u64) {
        let lat = if op_lat { self.model.op_lat_s } else { 0.0 };
        self.charge(lat + bytes as f64 / self.model.client_bps);
    }
}

/// The simulating decorator. Wrap any backend:
///
/// ```no_run
/// # use std::sync::Arc;
/// # use abhsf::vfs::{MemFs, SimFs, FaultSpec};
/// # use abhsf::parfs::FsModel;
/// let sim = SimFs::new(Arc::new(MemFs::new()), FsModel::anselm_lustre())
///     .faults(FaultSpec::parse("missing:matrix-1").unwrap());
/// ```
pub struct SimFs {
    inner: Arc<dyn Storage>,
    faults: FaultSpec,
    state: Arc<SimState>,
}

impl std::fmt::Debug for SimFs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "SimFs(over {:?}, {:.3}s simulated)",
            self.inner,
            self.simulated_seconds()
        )
    }
}

impl SimFs {
    /// Simulate `model` over `inner`, with no faults and no sleeping.
    pub fn new(inner: Arc<dyn Storage>, model: FsModel) -> Self {
        Self {
            inner,
            faults: FaultSpec::default(),
            state: Arc::new(SimState {
                model,
                clock_ns: AtomicU64::new(0),
                scale: 0.0,
            }),
        }
    }

    /// Sleep `scale` real seconds per simulated second (0 = account
    /// only, 1 = real-time emulation).
    pub fn time_scale(mut self, scale: f64) -> Self {
        self.state = Arc::new(SimState {
            model: self.state.model,
            clock_ns: AtomicU64::new(self.state.clock_ns.load(Ordering::Relaxed)),
            scale,
        });
        self
    }

    /// Install a fault specification.
    pub fn faults(mut self, faults: FaultSpec) -> Self {
        self.faults = faults;
        self
    }

    /// Simulated seconds accumulated across all operations so far.
    pub fn simulated_seconds(&self) -> f64 {
        self.state.clock_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    fn missing(&self, path: &Path) -> io::Result<()> {
        if FaultSpec::matches(&self.faults.missing, path) {
            return Err(io::Error::new(
                io::ErrorKind::NotFound,
                format!("injected fault: {} is missing", path.display()),
            ));
        }
        Ok(())
    }

    fn writable(&self, path: &Path) -> io::Result<()> {
        if FaultSpec::matches(&self.faults.fail_writes, path) {
            return Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                format!("injected fault: writes to {} fail", path.display()),
            ));
        }
        Ok(())
    }
}

/// Read handle decorator: charges per read, optionally truncates.
struct SimFile {
    inner: Arc<dyn StorageRead>,
    state: Arc<SimState>,
    /// `Some(limit)` when the truncation fault applies: the file claims
    /// to end at `limit` and reads beyond it fail.
    truncate_to: Option<u64>,
}

impl StorageRead for SimFile {
    fn read_exact_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        if let Some(limit) = self.truncate_to {
            if offset + buf.len() as u64 > limit {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!(
                        "injected fault: read [{offset}, {}) past simulated truncation at {limit}",
                        offset + buf.len() as u64
                    ),
                ));
            }
        }
        self.state.charge_bytes(true, buf.len() as u64);
        self.inner.read_exact_at(offset, buf)
    }

    fn len(&self) -> io::Result<u64> {
        match self.truncate_to {
            Some(limit) => Ok(limit),
            None => self.inner.len(),
        }
    }
}

/// Write handle decorator: charges per append.
struct SimWriter {
    inner: Box<dyn StorageWrite>,
    state: Arc<SimState>,
}

impl StorageWrite for SimWriter {
    fn append(&mut self, buf: &[u8]) -> io::Result<()> {
        self.state.charge_bytes(true, buf.len() as u64);
        self.inner.append(buf)
    }

    fn patch_at(&mut self, offset: u64, buf: &[u8]) -> io::Result<()> {
        self.state.charge_bytes(true, buf.len() as u64);
        self.inner.patch_at(offset, buf)
    }

    fn sync(&mut self) -> io::Result<()> {
        self.state.charge(self.state.model.op_lat_s);
        self.inner.sync()
    }
}

impl Storage for SimFs {
    fn open(&self, path: &Path) -> io::Result<Arc<dyn StorageRead>> {
        self.missing(path)?;
        self.state.charge(self.state.model.open_lat_s);
        let inner = self.inner.open(path)?;
        let truncate_to = if FaultSpec::matches(&self.faults.truncate, path) {
            Some(inner.len()? / 2)
        } else {
            None
        };
        Ok(Arc::new(SimFile {
            inner,
            state: Arc::clone(&self.state),
            truncate_to,
        }))
    }

    fn create(&self, path: &Path) -> io::Result<Box<dyn StorageWrite>> {
        self.writable(path)?;
        self.state.charge(self.state.model.open_lat_s);
        Ok(Box::new(SimWriter {
            inner: self.inner.create(path)?,
            state: Arc::clone(&self.state),
        }))
    }

    fn len(&self, path: &Path) -> io::Result<u64> {
        self.missing(path)?;
        self.state.charge(self.state.model.op_lat_s);
        let len = self.inner.len(path)?;
        if FaultSpec::matches(&self.faults.truncate, path) {
            return Ok(len / 2);
        }
        Ok(len)
    }

    fn list(&self, dir: &Path) -> io::Result<Vec<PathBuf>> {
        self.state.charge(self.state.model.op_lat_s);
        let mut out = self.inner.list(dir)?;
        out.retain(|p| !FaultSpec::matches(&self.faults.missing, p));
        Ok(out)
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        self.writable(to)?;
        self.state.charge(self.state.model.op_lat_s);
        self.inner.rename(from, to)
    }

    fn read_file(&self, path: &Path) -> io::Result<Vec<u8>> {
        self.missing(path)?;
        let mut bytes = self.inner.read_file(path)?;
        if FaultSpec::matches(&self.faults.truncate, path) {
            // Whole-file reads see the same half-length view `len` and
            // the positioned handles report.
            bytes.truncate(bytes.len() / 2);
        }
        self.state.charge_bytes(true, bytes.len() as u64);
        Ok(bytes)
    }

    fn write_file(&self, path: &Path, bytes: &[u8]) -> io::Result<()> {
        self.writable(path)?;
        self.state.charge_bytes(true, bytes.len() as u64);
        self.inner.write_file(path, bytes)
    }

    fn create_dir_all(&self, dir: &Path) -> io::Result<()> {
        self.inner.create_dir_all(dir)
    }

    fn canonical(&self, path: &Path) -> PathBuf {
        self.inner.canonical(path)
    }

    fn medium(&self) -> usize {
        self.inner.medium()
    }

    fn label(&self) -> &'static str {
        "sim"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vfs::MemFs;

    fn base() -> Arc<dyn Storage> {
        let fs = MemFs::new();
        fs.write_file(Path::new("/d/matrix-0.h5spm"), &[7u8; 100])
            .unwrap();
        fs.write_file(Path::new("/d/matrix-1.h5spm"), &[8u8; 100])
            .unwrap();
        Arc::new(fs)
    }

    #[test]
    fn fault_spec_parses() {
        let f = FaultSpec::parse("missing:matrix-1, truncate:matrix-0").unwrap();
        assert_eq!(f.missing.as_deref(), Some("matrix-1"));
        assert_eq!(f.truncate.as_deref(), Some("matrix-0"));
        assert!(f.fail_writes.is_none());
        assert!(FaultSpec::parse("").unwrap().missing.is_none());
        assert!(FaultSpec::parse("explode:everything").is_err());
        assert!(FaultSpec::parse("garbage").is_err());
        // A space after the colon must not silently disarm the fault.
        let f = FaultSpec::parse("truncate: matrix-0").unwrap();
        assert_eq!(f.truncate.as_deref(), Some("matrix-0"));
        // An empty pattern would match every path: rejected.
        assert!(FaultSpec::parse("missing:").is_err());
        assert!(FaultSpec::parse("missing:  ").is_err());
    }

    #[test]
    fn missing_fault_hides_matches_only() {
        let sim = SimFs::new(base(), FsModel::local_nvme())
            .faults(FaultSpec::parse("missing:matrix-1").unwrap());
        assert!(sim.open(Path::new("/d/matrix-0.h5spm")).is_ok());
        let err = sim.open(Path::new("/d/matrix-1.h5spm")).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::NotFound);
        assert!(sim.len(Path::new("/d/matrix-1.h5spm")).is_err());
        let listed = sim.list(Path::new("/d")).unwrap();
        assert_eq!(listed.len(), 1, "{listed:?}");
    }

    #[test]
    fn truncate_fault_halves_and_rejects_tail_reads() {
        let sim = SimFs::new(base(), FsModel::local_nvme())
            .faults(FaultSpec::parse("truncate:matrix-0").unwrap());
        let r = sim.open(Path::new("/d/matrix-0.h5spm")).unwrap();
        assert_eq!(r.len().unwrap(), 50);
        assert_eq!(sim.len(Path::new("/d/matrix-0.h5spm")).unwrap(), 50);
        // Whole-file reads agree with the truncated view.
        assert_eq!(sim.read_file(Path::new("/d/matrix-0.h5spm")).unwrap().len(), 50);
        let mut buf = [0u8; 10];
        r.read_exact_at(40, &mut buf).unwrap();
        let err = r.read_exact_at(45, &mut buf).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        // The untouched file reads in full.
        let r1 = sim.open(Path::new("/d/matrix-1.h5spm")).unwrap();
        assert_eq!(r1.len().unwrap(), 100);
    }

    #[test]
    fn write_fault_rejects_cleanly() {
        let inner = MemFs::new();
        let sim = SimFs::new(Arc::new(inner.clone()), FsModel::local_nvme())
            .faults(FaultSpec::parse("fail-writes:dataset.json").unwrap());
        let err = sim
            .write_file(Path::new("/d/dataset.json"), b"{}")
            .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::PermissionDenied);
        assert!(sim.create(Path::new("/d/dataset.json")).is_err());
        assert!(
            inner.read_file(Path::new("/d/dataset.json")).is_err(),
            "failed write must leave nothing behind"
        );
        // Other writes pass through.
        sim.write_file(Path::new("/d/other"), b"ok").unwrap();
    }

    #[test]
    fn clock_accumulates_model_costs() {
        let sim = SimFs::new(base(), FsModel::anselm_lustre());
        assert_eq!(sim.simulated_seconds(), 0.0);
        let r = sim.open(Path::new("/d/matrix-0.h5spm")).unwrap();
        let mut buf = [0u8; 64];
        r.read_exact_at(0, &mut buf).unwrap();
        let m = FsModel::anselm_lustre();
        let want = m.open_lat_s + m.op_lat_s + 64.0 / m.client_bps;
        assert!(
            (sim.simulated_seconds() - want).abs() < 1e-9,
            "{} vs {want}",
            sim.simulated_seconds()
        );
    }
}
