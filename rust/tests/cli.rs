//! CLI integration: drive the compiled `abhsf` binary end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_abhsf"))
}

fn run_ok(args: &[&str]) -> String {
    let out = bin().args(args).output().expect("binary runs");
    assert!(
        out.status.success(),
        "abhsf {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).to_string()
}

#[test]
fn help_lists_subcommands() {
    let out = run_ok(&["help"]);
    for sub in [
        "generate",
        "store",
        "info",
        "load",
        "roundtrip",
        "repack",
        "spmv",
        "serve",
        "served",
        "trace",
        "stats",
        "fig1",
        "remote:HOST:PORT",
    ] {
        assert!(out.contains(sub), "help missing {sub}");
    }
}

/// An unknown `--backend` is a *usage* mistake: exit code 2 with the
/// usage text, like an unknown subcommand — not a panic, not a generic
/// runtime error.
#[test]
fn unknown_backend_is_usage_error() {
    let out = bin()
        .args(["load", "--dir", "/nonexistent", "--backend", "floppy"])
        .output()
        .unwrap();
    assert_eq!(
        out.status.code(),
        Some(2),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stdout.contains("Usage:"), "no usage text:\n{stdout}");
    assert!(stderr.contains("usage error"), "{stderr}");
    assert!(stderr.contains("floppy"), "{stderr}");
}

/// A malformed `--fault` spec likewise exits 2 with usage, naming the
/// bad spec.
#[test]
fn malformed_fault_spec_is_usage_error() {
    let out = bin()
        .args([
            "load", "--dir", "/nonexistent", "--backend", "sim", "--fault", "explode:matrix-0",
        ])
        .output()
        .unwrap();
    assert_eq!(
        out.status.code(),
        Some(2),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stdout.contains("Usage:"), "no usage text:\n{stdout}");
    assert!(stderr.contains("usage error"), "{stderr}");
    assert!(stderr.contains("fault"), "{stderr}");
}

#[test]
fn unknown_subcommand_fails_with_usage() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stdout.contains("Usage:"), "no usage on unknown subcommand");
    assert!(stderr.contains("frobnicate"), "{stderr}");
}

#[test]
fn generate_describes_workload() {
    let out = run_ok(&["generate", "--seed-size", "8", "--order", "2", "--procs", "3"]);
    assert!(out.contains("dimension"), "{out}");
    assert!(out.contains("64 x 64"), "{out}");
    assert!(out.contains("balanced row-wise"), "{out}");
}

#[test]
fn store_info_load_cycle() {
    let dir = std::env::temp_dir().join(format!("abhsf-cli-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dirs = dir.to_str().unwrap();

    let out = run_ok(&[
        "store", "--dir", dirs, "--seed-size", "8", "--procs", "3", "--block-size", "16",
    ]);
    assert!(out.contains("stored"), "{out}");

    let out = run_ok(&["info", "--dir", dirs]);
    assert!(out.contains("matrix-0"), "{out}");
    assert!(out.contains("matrix-2"), "{out}");

    let out = run_ok(&["load", "--dir", dirs, "--same-config"]);
    assert!(out.contains("same-config"), "{out}");
    assert!(out.contains("sim (Lustre)"), "{out}");

    let out = run_ok(&[
        "load", "--dir", dirs, "--procs", "4", "--mapping", "colwise", "--strategy",
        "collective",
    ]);
    assert!(out.contains("diff-config/collective"), "{out}");

    let out = run_ok(&[
        "load", "--dir", dirs, "--procs", "2", "--strategy", "exchange",
    ]);
    assert!(out.contains("diff-config/exchange"), "{out}");

    // The help-advertised 2d / cyclic target mappings parse on `load` too.
    let out = run_ok(&[
        "load", "--dir", dirs, "--procs", "4", "--mapping", "2d", "--strategy", "independent",
    ]);
    assert!(out.contains("diff-config/independent"), "{out}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn load_reports_block_pruning_and_auto_decision() {
    let dir = std::env::temp_dir().join(format!("abhsf-cli-prune-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dirs = dir.to_str().unwrap();
    run_ok(&[
        "store", "--dir", dirs, "--seed-size", "8", "--procs", "3", "--block-size", "8",
    ]);

    // A rowwise->colwise remap prunes blocks; the report must say so.
    let out = run_ok(&[
        "load", "--dir", dirs, "--procs", "4", "--mapping", "colwise", "--strategy",
        "independent",
    ]);
    assert!(out.contains("block pruning"), "{out}");
    assert!(out.contains("blocks skipped"), "{out}");
    assert!(out.contains("payload skipped"), "{out}");

    // --no-prune restores the literal decode-everything loop: no pruning
    // line in the report.
    let out = run_ok(&[
        "load", "--dir", dirs, "--procs", "4", "--mapping", "colwise", "--strategy",
        "independent", "--no-prune",
    ]);
    assert!(!out.contains("block pruning"), "{out}");

    // --strategy auto prints the recorded decision with its candidates.
    let out = run_ok(&[
        "load", "--dir", dirs, "--procs", "4", "--mapping", "colwise", "--strategy", "auto",
    ]);
    assert!(out.contains("auto strategy"), "{out}");
    assert!(out.contains("predicted:"), "{out}");
    assert!(out.contains("independent"), "{out}");

    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance scenario end to end: store row-wise with P=4, repack to
/// a 2×3 Block2d grid with a new block size, and use `spmv` (power
/// iteration) as the smoke test. The loaded *elements* are bitwise
/// identical (asserted in the repack unit/differential tests); the SpMV
/// numbers are compared to 1e-9 relative, because a Block2d layout splits
/// rows across parts and regroups the per-row FP summation.
#[test]
fn repack_then_spmv_matches_original() {
    let dir = std::env::temp_dir().join(format!("abhsf-cli-repack-{}", std::process::id()));
    let out_dir = std::env::temp_dir().join(format!("abhsf-cli-repack-out-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&out_dir);
    let dirs = dir.to_str().unwrap();
    let outs = out_dir.to_str().unwrap();

    run_ok(&[
        "store", "--dir", dirs, "--seed-size", "8", "--procs", "4", "--block-size", "8",
    ]);
    // Per-iteration |A x|, the eigenvalue estimate and the residual, as
    // printed by `abhsf spmv` (last token of each metric line).
    let spmv_metrics = |dir: &str| -> Vec<f64> {
        run_ok(&["spmv", "--dir", dir, "--iters", "5"])
            .lines()
            .filter(|l| l.contains("|A x|_2") || l.contains("eigenvalue") || l.contains("residual"))
            .map(|l| {
                l.split_whitespace()
                    .last()
                    .unwrap()
                    .parse::<f64>()
                    .unwrap_or_else(|_| panic!("unparsable metric line: {l}"))
            })
            .collect()
    };
    let before = spmv_metrics(dirs);
    assert!(before.len() >= 7, "spmv printed too little: {before:?}");

    let out = run_ok(&[
        "repack", "--dir", dirs, "--out", outs, "--nprocs", "6", "--mapping", "2d",
        "--block-size", "16", "--chunk-size", "512",
    ]);
    assert!(out.contains("repacked"), "{out}");
    assert!(out.contains("block pruning"), "{out}");
    assert!(out.contains("peak staging"), "{out}");
    assert!(out.contains("forecast"), "{out}");

    let after = spmv_metrics(outs);
    assert_eq!(before.len(), after.len(), "{before:?} vs {after:?}");
    for (i, (b, a)) in before.iter().zip(&after).enumerate() {
        assert!(
            (b - a).abs() <= 1e-9 * b.abs().max(1.0),
            "spmv metric {i} diverged after repack: {b} vs {a}"
        );
    }

    // A repack into the source directory itself must be refused.
    let err = bin()
        .args(["repack", "--dir", outs, "--out", outs])
        .output()
        .unwrap();
    assert!(!err.status.success());

    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&out_dir);
}

#[test]
fn roundtrip_subcommand() {
    let out = run_ok(&["roundtrip", "--seed-size", "8", "--procs", "2"]);
    assert!(out.contains("roundtrip OK"), "{out}");
}

/// `--backend mem`: the full store → load → SpMV cycle without touching
/// the disk (one-process run; the map is shared across worker threads).
#[test]
fn backend_mem_roundtrip() {
    let out = run_ok(&[
        "roundtrip", "--seed-size", "8", "--procs", "2", "--backend", "mem",
    ]);
    assert!(out.contains("roundtrip OK"), "{out}");
    assert!(out.contains("backend mem"), "{out}");
}

/// `--backend sim`: fault injection surfaces as a clean `error:` exit
/// (status 1), never a panic; fault-free simulation reports the
/// parfs-model clock.
#[test]
fn backend_sim_faults_and_clock() {
    let dir = std::env::temp_dir().join(format!("abhsf-cli-sim-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dirs = dir.to_str().unwrap();
    run_ok(&[
        "store", "--dir", dirs, "--seed-size", "8", "--procs", "2", "--block-size", "8",
    ]);

    // Injected truncation: typed error, exit code 1 (a worker panic
    // would exit 101).
    let out = bin()
        .args([
            "load", "--dir", dirs, "--same-config", "--backend", "sim", "--fault",
            "truncate:matrix-0",
        ])
        .output()
        .unwrap();
    assert_eq!(
        out.status.code(),
        Some(1),
        "stdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("error"), "{stderr}");

    // Fault-free simulation loads fine and prints the simulated clock.
    let out = run_ok(&["load", "--dir", dirs, "--same-config", "--backend", "sim"]);
    assert!(out.contains("sim backend"), "{out}");

    let _ = std::fs::remove_dir_all(&dir);
}

/// `serve --backend mem` is self-contained: the dataset is generated,
/// stored and queried in one invocation, and the report ends with the
/// throughput/latency/cache lines the CI smoke greps for.
#[test]
fn serve_mem_backend_self_contained() {
    let out = run_ok(&[
        "serve", "--backend", "mem", "--seed-size", "8", "--procs", "2", "--threads", "4",
        "--queries", "64", "--budget", "1MiB",
    ]);
    assert!(out.contains("stored"), "{out}");
    assert!(out.contains("throughput"), "{out}");
    assert!(out.contains("latency"), "{out}");
    assert!(out.contains("hit rate"), "{out}");
}

/// Serving several `--dir`s prints the per-dataset breakdown (one
/// `dataset LABEL:` line each) on top of the aggregate report, the
/// two-tier `tiers` line is always present, and skewed workloads parse.
#[test]
fn serve_multiple_dirs_reports_per_dataset() {
    let out = run_ok(&[
        "serve", "--backend", "mem", "--dir", "alpha,beta", "--seed-size", "8", "--procs",
        "2", "--threads", "2", "--queries", "48", "--budget", "256KiB", "--workload",
        "zipf:1.1",
    ]);
    assert!(out.contains("workload zipf:1.1"), "{out}");
    assert!(out.contains("tiers"), "{out}");
    assert!(out.contains("budget plan"), "{out}");
    assert!(out.contains("dataset alpha:"), "{out}");
    assert!(out.contains("dataset beta:"), "{out}");

    // A single dataset keeps the report aggregate-only.
    let single = run_ok(&[
        "serve", "--backend", "mem", "--seed-size", "8", "--procs", "2", "--threads", "2",
        "--queries", "32", "--budget", "256KiB",
    ]);
    assert!(single.contains("tiers"), "{single}");
    assert!(!single.contains("dataset matrix:"), "{single}");
}

/// A malformed `--workload` is a usage mistake: exit 2 with usage text,
/// naming the bad spec.
#[test]
fn malformed_workload_is_usage_error() {
    for bad in ["zipf", "zipf:-1", "hotspot:0", "pareto"] {
        let out = bin()
            .args([
                "serve", "--backend", "mem", "--seed-size", "8", "--procs", "2",
                "--queries", "8", "--workload", bad,
            ])
            .output()
            .unwrap();
        assert_eq!(
            out.status.code(),
            Some(2),
            "workload {bad}: stderr: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains("workload"), "workload {bad}: {stderr}");
    }
}

/// `serve` against a previously stored dataset on disk; a missing
/// dataset without `--gen` stays a clean error.
#[test]
fn serve_on_stored_dataset() {
    let dir = std::env::temp_dir().join(format!("abhsf-cli-serve-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dirs = dir.to_str().unwrap();
    run_ok(&[
        "store", "--dir", dirs, "--seed-size", "8", "--procs", "3", "--block-size", "8",
    ]);
    let out = run_ok(&[
        "serve", "--dir", dirs, "--threads", "2", "--queries", "40", "--budget", "256KiB",
    ]);
    assert!(out.contains("throughput"), "{out}");
    assert!(out.contains("hit rate"), "{out}");

    let err = bin()
        .args(["serve", "--dir", "/nonexistent-abhsf-serve-dir"])
        .output()
        .unwrap();
    assert!(!err.status.success(), "missing dataset must fail without --gen");

    let _ = std::fs::remove_dir_all(&dir);
}

/// `--trace` on a self-contained serve run writes a well-formed JSONL
/// span trace: unique ids, every span closed, parents resolving to
/// earlier spans (validated through the library checker), and the
/// `trace` subcommand summarizes it — per-kind totals, cache-claim
/// outcomes, and an example query chain reconstructed from parent links.
#[test]
fn traced_serve_writes_summarizable_trace() {
    let path = std::env::temp_dir().join(format!("abhsf-cli-trace-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let paths = path.to_str().unwrap();
    let out = run_ok(&[
        "serve", "--backend", "mem", "--seed-size", "8", "--procs", "2", "--threads", "2",
        "--queries", "64", "--budget", "256KiB", "--trace", paths, "--metrics",
    ]);
    assert!(out.contains("throughput"), "{out}");
    assert!(out.contains("p99.9"), "{out}");
    assert!(out.contains("metric serve.latency_s"), "{out}");
    assert!(out.contains("metric serve.queries = 64"), "{out}");
    assert!(out.contains("metric cache.claim.miss"), "{out}");

    let events = abhsf::obs::trace::read_trace(&path).expect("trace parses as JSONL");
    abhsf::obs::trace::check(&events).expect("trace is well formed");
    assert!(
        events.iter().any(|e| e.kind == "query"),
        "no query spans in the trace"
    );

    let summary = run_ok(&["trace", paths]);
    for needle in [
        "events",
        "query",
        "cache_claim outcomes",
        "vfs_read",
        "block_decode",
        "slowest spans",
        "example query chain",
    ] {
        assert!(summary.contains(needle), "summary missing {needle}:\n{summary}");
    }

    let _ = std::fs::remove_file(&path);
}

/// `trace` on a missing file is a runtime error; without a file at all
/// it is a usage mistake (exit 2).
#[test]
fn trace_subcommand_error_paths() {
    let out = bin().args(["trace"]).output().unwrap();
    assert_eq!(
        out.status.code(),
        Some(2),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let out = bin()
        .args(["trace", "/nonexistent-abhsf-trace.jsonl"])
        .output()
        .unwrap();
    assert_eq!(
        out.status.code(),
        Some(1),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
}

/// `stats` needs a remote backend (usage error without one) and, pointed
/// at a live `pallas-served` daemon, reports the server's lifetime
/// counters.
#[test]
fn stats_queries_live_daemon() {
    let out = bin().args(["stats"]).output().unwrap();
    assert_eq!(
        out.status.code(),
        Some(2),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("remote:"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );

    let mut handle = abhsf::net::serve(
        std::sync::Arc::new(abhsf::vfs::MemFs::new()),
        "127.0.0.1:0",
        abhsf::net::ServeOptions::default(),
    )
    .expect("bind ephemeral daemon");
    let backend = format!("remote:{}", handle.addr());
    let out = run_ok(&["stats", "--backend", &backend]);
    for needle in ["pallas-served", "ping", "requests", "errors", "uptime", "probe client"] {
        assert!(out.contains(needle), "stats missing {needle}:\n{out}");
    }
    handle.shutdown();
}

#[test]
fn fig1_quick_run() {
    let out = run_ok(&[
        "fig1",
        "--seed-size",
        "8",
        "--store-procs",
        "3",
        "--procs",
        "2,4",
        "--reps",
        "1",
    ]);
    assert!(out.contains("same-config"), "{out}");
    assert!(out.contains("diff/independent"), "{out}");
    assert!(out.contains("diff/collective"), "{out}");
    assert!(out.contains("paper shape checks"), "{out}");
}

#[test]
fn load_on_missing_dir_is_clean_error() {
    let out = bin()
        .args(["load", "--dir", "/nonexistent-abhsf-dir"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("error"), "{err}");
}
