//! CLI integration: drive the compiled `abhsf` binary end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_abhsf"))
}

fn run_ok(args: &[&str]) -> String {
    let out = bin().args(args).output().expect("binary runs");
    assert!(
        out.status.success(),
        "abhsf {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).to_string()
}

#[test]
fn help_lists_subcommands() {
    let out = run_ok(&["help"]);
    for sub in ["generate", "store", "info", "load", "roundtrip", "spmv", "fig1"] {
        assert!(out.contains(sub), "help missing {sub}");
    }
}

#[test]
fn unknown_subcommand_fails() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn generate_describes_workload() {
    let out = run_ok(&["generate", "--seed-size", "8", "--order", "2", "--procs", "3"]);
    assert!(out.contains("dimension"), "{out}");
    assert!(out.contains("64 x 64"), "{out}");
    assert!(out.contains("balanced row-wise"), "{out}");
}

#[test]
fn store_info_load_cycle() {
    let dir = std::env::temp_dir().join(format!("abhsf-cli-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dirs = dir.to_str().unwrap();

    let out = run_ok(&[
        "store", "--dir", dirs, "--seed-size", "8", "--procs", "3", "--block-size", "16",
    ]);
    assert!(out.contains("stored"), "{out}");

    let out = run_ok(&["info", "--dir", dirs]);
    assert!(out.contains("matrix-0"), "{out}");
    assert!(out.contains("matrix-2"), "{out}");

    let out = run_ok(&["load", "--dir", dirs, "--same-config"]);
    assert!(out.contains("same-config"), "{out}");
    assert!(out.contains("sim (Lustre)"), "{out}");

    let out = run_ok(&[
        "load", "--dir", dirs, "--procs", "4", "--mapping", "colwise", "--strategy",
        "collective",
    ]);
    assert!(out.contains("diff-config/collective"), "{out}");

    let out = run_ok(&[
        "load", "--dir", dirs, "--procs", "2", "--strategy", "exchange",
    ]);
    assert!(out.contains("diff-config/exchange"), "{out}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn load_reports_block_pruning_and_auto_decision() {
    let dir = std::env::temp_dir().join(format!("abhsf-cli-prune-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dirs = dir.to_str().unwrap();
    run_ok(&[
        "store", "--dir", dirs, "--seed-size", "8", "--procs", "3", "--block-size", "8",
    ]);

    // A rowwise->colwise remap prunes blocks; the report must say so.
    let out = run_ok(&[
        "load", "--dir", dirs, "--procs", "4", "--mapping", "colwise", "--strategy",
        "independent",
    ]);
    assert!(out.contains("block pruning"), "{out}");
    assert!(out.contains("blocks skipped"), "{out}");
    assert!(out.contains("payload skipped"), "{out}");

    // --no-prune restores the literal decode-everything loop: no pruning
    // line in the report.
    let out = run_ok(&[
        "load", "--dir", dirs, "--procs", "4", "--mapping", "colwise", "--strategy",
        "independent", "--no-prune",
    ]);
    assert!(!out.contains("block pruning"), "{out}");

    // --strategy auto prints the recorded decision with its candidates.
    let out = run_ok(&[
        "load", "--dir", dirs, "--procs", "4", "--mapping", "colwise", "--strategy", "auto",
    ]);
    assert!(out.contains("auto strategy"), "{out}");
    assert!(out.contains("predicted:"), "{out}");
    assert!(out.contains("independent"), "{out}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn roundtrip_subcommand() {
    let out = run_ok(&["roundtrip", "--seed-size", "8", "--procs", "2"]);
    assert!(out.contains("roundtrip OK"), "{out}");
}

#[test]
fn fig1_quick_run() {
    let out = run_ok(&[
        "fig1",
        "--seed-size",
        "8",
        "--store-procs",
        "3",
        "--procs",
        "2,4",
        "--reps",
        "1",
    ]);
    assert!(out.contains("same-config"), "{out}");
    assert!(out.contains("diff/independent"), "{out}");
    assert!(out.contains("diff/collective"), "{out}");
    assert!(out.contains("paper shape checks"), "{out}");
}

#[test]
fn load_on_missing_dir_is_clean_error() {
    let out = bin()
        .args(["load", "--dir", "/nonexistent-abhsf-dir"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("error"), "{err}");
}
