//! Dataset/LoadPlan API: manifest round-trip, `Strategy::Auto`
//! selection, legacy-directory discovery, and storage-backend plumbing.

use std::collections::HashMap;
use std::sync::Arc;

use abhsf::coordinator::{
    Cluster, Dataset, DatasetError, InMemFormat, StoreOptions, Strategy, MANIFEST_FILE,
};
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::mapping::{Colwise, ProcessMapping, Rowwise};
use abhsf::vfs::MemFs;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("abhsf-dataset-api").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn workload() -> Arc<KroneckerGen> {
    Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 17), 2))
}

/// Global element map of loaded parts, for content equality checks.
fn collect(mats: &[abhsf::coordinator::LoadedMatrix]) -> HashMap<(u64, u64), f64> {
    let mut m = HashMap::new();
    for lm in mats {
        let coo = lm.clone().into_coo();
        let (ro, co) = (coo.info.m_offset, coo.info.n_offset);
        for (r, c, v) in coo.iter() {
            assert!(m.insert((r + ro, c + co), v).is_none());
        }
    }
    m
}

#[test]
fn manifest_roundtrip_discovers_store_configuration() {
    let gen = workload();
    let n = gen.dim();
    let p_store = 4;
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p_store));
    let cluster = Cluster::new(p_store, 64);
    let dir = tmpdir("roundtrip");
    let (stored, report) = Dataset::store(
        &cluster,
        &gen,
        &mapping,
        &dir,
        StoreOptions {
            block_size: 8,
            ..Default::default()
        },
    )
    .unwrap();

    // Reopen from disk: everything the loader needs is discovered.
    let reopened = Dataset::open(&dir).unwrap();
    assert_eq!(reopened.nprocs(), p_store);
    assert_eq!(reopened.dims(), (n, n));
    assert_eq!(reopened.nnz(), gen.nnz());
    assert_eq!(reopened.block_size(), 8);
    assert_eq!(reopened.mapping(), &mapping.descriptor());
    assert!(reopened.mapping().same_mapping(stored.mapping()));
    assert_eq!(reopened.manifest(), stored.manifest());
    // Per-file accounting matches the store report and the disk.
    let files = &reopened.manifest().files;
    assert_eq!(files.len(), p_store);
    for (k, f) in files.iter().enumerate() {
        assert_eq!(f.nnz, report.per_rank_nnz[k], "file {k} nnz");
        let on_disk = std::fs::metadata(abhsf::abhsf::matrix_file_path(&dir, k))
            .unwrap()
            .len();
        assert_eq!(f.bytes, on_disk, "file {k} bytes");
    }
}

#[test]
fn auto_takes_fast_path_on_matching_configuration() {
    let gen = workload();
    let n = gen.dim();
    let p = 3;
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p));
    let cluster = Cluster::new(p, 64);
    let dir = tmpdir("auto-same");
    Dataset::store(&cluster, &gen, &mapping, &dir, StoreOptions::default()).unwrap();

    let dataset = Dataset::open(&dir).unwrap();
    // Same P, same mapping, explicitly supplied: fast path.
    let (mats, report) = dataset
        .load()
        .nprocs(p)
        .mapping(&mapping)
        .strategy(Strategy::Auto)
        .format(InMemFormat::Csr)
        .run(&cluster)
        .unwrap();
    assert_eq!(report.scenario, "same-config");
    let auto = report.auto.as_ref().expect("auto decision recorded");
    assert!(auto.same_config);
    assert_eq!(auto.chosen, "same-config");
    assert!(auto.predicted.iter().any(|(l, _)| l == "same-config"));
    // The fast path reads each file exactly once, by its own rank.
    for io in &report.per_rank_io {
        assert_eq!(io.opens, 1);
    }
    assert_eq!(report.total_nnz(), gen.nnz());
    assert_eq!(mats.len(), p);
}

#[test]
fn auto_falls_back_to_diff_config_on_mismatch() {
    let gen = workload();
    let n = gen.dim();
    let p_store = 3;
    let store_map: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p_store));
    let store_cluster = Cluster::new(p_store, 64);
    let dir = tmpdir("auto-diff");
    Dataset::store(&store_cluster, &gen, &store_map, &dir, StoreOptions::default()).unwrap();
    let dataset = Dataset::open(&dir).unwrap();

    // Different process count: must not fast-path.
    let p_load = 5;
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, p_load));
    let cluster = Cluster::new(p_load, 64);
    let (mats, report) = dataset
        .load()
        .mapping(&mapping)
        .strategy(Strategy::Auto)
        .run(&cluster)
        .unwrap();
    let auto = report.auto.as_ref().expect("auto decision recorded");
    assert!(!auto.same_config);
    assert_ne!(auto.chosen, "same-config");
    assert!(
        report.scenario.starts_with("diff-config/"),
        "{}",
        report.scenario
    );
    assert!(report.scenario.ends_with(&auto.chosen), "{}", report.scenario);
    // The winner is the cheapest predicted candidate.
    let min = auto
        .predicted
        .iter()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap();
    assert_eq!(min.0, auto.chosen);
    assert_eq!(report.total_nnz(), gen.nnz());
    assert_eq!(mats.len(), p_load);

    // Same process count but a *different* mapping: also no fast path.
    let colwise_same_p: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, p_store));
    let (_, report) = dataset
        .load()
        .mapping(&colwise_same_p)
        .strategy(Strategy::Auto)
        .run(&store_cluster)
        .unwrap();
    assert!(!report.auto.as_ref().unwrap().same_config);
}

#[test]
fn auto_and_explicit_loads_agree_on_content() {
    let gen = workload();
    let n = gen.dim();
    let p_store = 4;
    let store_map: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p_store));
    let store_cluster = Cluster::new(p_store, 64);
    let dir = tmpdir("content");
    Dataset::store(&store_cluster, &gen, &store_map, &dir, StoreOptions::default()).unwrap();
    let dataset = Dataset::open(&dir).unwrap();

    let (same_mats, _) = dataset.load().run(&store_cluster).unwrap();
    let want = collect(&same_mats);

    let p_load = 2;
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, p_load));
    let cluster = Cluster::new(p_load, 64);
    for strategy in [
        Strategy::Auto,
        Strategy::Independent,
        Strategy::Collective,
        Strategy::Exchange,
    ] {
        let (mats, _) = dataset
            .load()
            .mapping(&mapping)
            .strategy(strategy)
            .format(InMemFormat::Coo)
            .run(&cluster)
            .unwrap();
        assert_eq!(collect(&mats), want, "{strategy}");
    }
}

#[test]
fn legacy_directory_without_manifest_still_opens() {
    let gen = workload();
    let n = gen.dim();
    let p = 3;
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p));
    let cluster = Cluster::new(p, 64);
    let dir = tmpdir("legacy");
    Dataset::store(&cluster, &gen, &mapping, &dir, StoreOptions::default()).unwrap();
    // Simulate a pre-manifest directory.
    std::fs::remove_file(dir.join(MANIFEST_FILE)).unwrap();

    let dataset = Dataset::open(&dir).unwrap();
    assert_eq!(dataset.nprocs(), p);
    assert_eq!(dataset.dims(), (n, n));
    assert_eq!(dataset.nnz(), gen.nnz());
    // The mapping cannot be reconstructed from headers alone...
    assert_eq!(dataset.mapping().kind(), "opaque");
    // ...but loading with the stored process count still fast-paths
    // (no mapping requested means "as stored").
    let (_, report) = dataset.load().run(&cluster).unwrap();
    assert_eq!(report.scenario, "same-config");
    assert_eq!(report.total_nnz(), gen.nnz());
    // An explicit mapping with matching P is NOT provably the stored
    // one (opaque), so auto must go through a diff-config strategy.
    let (_, report) = dataset.load().mapping(&mapping).run(&cluster).unwrap();
    assert!(!report.auto.as_ref().unwrap().same_config);
}

#[test]
fn empty_directory_is_not_a_dataset() {
    let dir = tmpdir("empty");
    let err = Dataset::open(&dir).expect_err("nothing to open");
    assert!(matches!(err, DatasetError::NotADataset { .. }), "{err}");
}

#[test]
fn partially_deleted_legacy_directory_is_rejected() {
    // Without a manifest the file scan stops at the first gap; the
    // header cross-check must refuse to open the remnant as a smaller
    // "valid" dataset (which would silently load a subset).
    let gen = workload();
    let n = gen.dim();
    let p = 3;
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p));
    let cluster = Cluster::new(p, 64);
    let dir = tmpdir("legacy-partial");
    Dataset::store(&cluster, &gen, &mapping, &dir, StoreOptions::default()).unwrap();
    std::fs::remove_file(dir.join(MANIFEST_FILE)).unwrap();
    std::fs::remove_file(abhsf::abhsf::matrix_file_path(&dir, 1)).unwrap();
    let err = Dataset::open(&dir).expect_err("partial legacy dir must not open");
    assert!(matches!(err, DatasetError::NotADataset { .. }), "{err}");
    assert!(format!("{err}").contains("incomplete"), "{err}");
}

#[test]
fn plan_validation_is_typed() {
    let gen = workload();
    let n = gen.dim();
    let p = 2;
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p));
    let cluster = Cluster::new(p, 64);
    let dir = tmpdir("validation");
    let (dataset, _) =
        Dataset::store(&cluster, &gen, &mapping, &dir, StoreOptions::default()).unwrap();

    // nprocs disagrees with the cluster.
    let err = dataset.load().nprocs(4).run(&cluster).unwrap_err();
    assert!(matches!(
        err,
        DatasetError::ClusterMismatch {
            cluster: 2,
            required: 4,
            ..
        }
    ));

    // Mapping P disagrees with the plan's nprocs.
    let wrong: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, 5));
    let err = dataset.load().mapping(&wrong).run(&cluster).unwrap_err();
    assert!(matches!(
        err,
        DatasetError::MappingMismatch {
            mapping: 5,
            nprocs: 2
        }
    ));

    // Different P without a target mapping.
    let big = Cluster::new(3, 64);
    let err = dataset.load().run(&big).unwrap_err();
    assert!(matches!(
        err,
        DatasetError::MappingRequired {
            nprocs: 3,
            stored: 2
        }
    ));
}

/// The whole store → manifest → open → load cycle runs unchanged over
/// the in-memory backend, and its contents agree with a local-disk store
/// of the same workload — the two-backend equivalence at the heart of
/// storage virtualization.
#[test]
fn memfs_store_load_agrees_with_localfs() {
    let gen = workload();
    let n = gen.dim();
    let p = 2;
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p));
    let cluster = Cluster::new(p, 64);

    let dir = tmpdir("backend-local");
    let (on_disk, disk_report) =
        Dataset::store(&cluster, &gen, &mapping, &dir, StoreOptions::default()).unwrap();
    let (mats_disk, _) = on_disk.load().run(&cluster).unwrap();

    let mem = MemFs::new();
    let mem_storage: Arc<dyn abhsf::vfs::Storage> = Arc::new(mem.clone());
    let (in_mem, mem_report) = Dataset::store_on(
        Arc::clone(&mem_storage),
        &cluster,
        &gen,
        &mapping,
        "/mem/backend",
        StoreOptions::default(),
    )
    .unwrap();
    assert_eq!(mem_report.total_nnz(), disk_report.total_nnz());
    assert!(mem.total_bytes() > 0, "nothing landed in the map");

    // Reopen through the backend: the manifest is discovered from MemFs.
    let reopened = Dataset::open_on(Arc::clone(&mem_storage), "/mem/backend").unwrap();
    assert_eq!(reopened.nprocs(), in_mem.nprocs());
    let (mats_mem, report) = reopened.load().run(&cluster).unwrap();
    assert_eq!(report.scenario, "same-config");
    assert_eq!(collect(&mats_mem), collect(&mats_disk), "backends diverged");

    // And nothing of the in-memory dataset ever touched the disk.
    assert!(!std::path::Path::new("/mem/backend").exists());
}
