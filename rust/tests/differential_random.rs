//! Randomized differential harness: every loading strategy must agree.
//!
//! A seeded generator (crate RNG — no `proptest` offline) draws ~30
//! configurations: random dims, densities, block sizes, storing/loading
//! process counts and mapping kinds. Each configuration is stored once
//! and reloaded through every strategy — the same-config fast path where
//! applicable, all-read-all independent/collective with block pruning on
//! *and* off, and the exchange loader — and all results must be
//! element-identical to the generated truth with matching `total_nnz`.
//!
//! The master seed comes from `ABHSF_DIFF_SEED` (default below) so CI and
//! local runs are reproducible; every assertion message carries the seed
//! and the configuration index needed to replay a failure.
//!
//! Configurations run on the in-memory [`MemFs`] backend by default —
//! same bytes, no disk I/O or per-file fsyncs across the ~40 random
//! stores — with the first [`LOCALFS_CONFIGS`] configurations of each
//! property pinned to the real filesystem so real-disk coverage never
//! disappears.

use std::collections::HashSet;
use std::sync::Arc;

use abhsf::cache::BlockCache;
use abhsf::coordinator::{Cluster, Dataset, InMemFormat, LoadedMatrix, StoreOptions, Strategy};
use abhsf::formats::element::tight_window;
use abhsf::formats::{Coo, LocalInfo};
use abhsf::mapping::{Block2d, Colwise, CyclicRows, ProcessMapping, Rowwise};
use abhsf::util::rng::Xoshiro256;
use abhsf::vfs::{MemFs, Storage};

const DEFAULT_SEED: u64 = 0xD1FF_2026;
const CONFIGS: usize = 30;

/// Configurations 0..LOCALFS_CONFIGS of each property stay on LocalFs.
const LOCALFS_CONFIGS: usize = 2;

/// The backend for configuration `idx`: a fresh in-memory namespace,
/// except the pinned real-disk configurations.
fn storage_for(idx: usize) -> Arc<dyn Storage> {
    if idx < LOCALFS_CONFIGS {
        abhsf::vfs::local()
    } else {
        Arc::new(MemFs::new())
    }
}

fn master_seed() -> u64 {
    match std::env::var("ABHSF_DIFF_SEED") {
        Ok(s) => s
            .trim()
            .parse()
            .unwrap_or_else(|_| panic!("ABHSF_DIFF_SEED={s:?} is not a u64")),
        Err(_) => DEFAULT_SEED,
    }
}

/// One drawn configuration (Debug is the reproduction recipe).
#[derive(Debug)]
struct Cfg {
    m: u64,
    n: u64,
    nnz: usize,
    block_size: u64,
    chunk_elems: u64,
    p_store: usize,
    store_kind: usize,
    p_load: usize,
    load_kind: usize,
}

fn draw_cfg(rng: &mut Xoshiro256, idx: usize) -> Cfg {
    let m = 8 + rng.next_below(89); // 8..=96
    let n = 8 + rng.next_below(89);
    let density = 0.01 + rng.next_f64() * 0.3;
    let nnz = (((m * n) as f64 * density) as usize).clamp(1, (m * n) as usize);
    let block_size = [2u64, 3, 4, 8, 16, 32][rng.range_usize(0, 6)];
    // Small container chunks so pruned range reads cross chunk seams.
    let chunk_elems = [16u64, 128, 65536][rng.range_usize(0, 3)];
    let p_store = 1 + rng.range_usize(0, 6);
    let store_kind = rng.range_usize(0, 4);
    // Every fifth config reloads with the storing configuration, so the
    // same-config fast path is part of the differential set.
    let (p_load, load_kind) = if idx % 5 == 0 {
        (p_store, store_kind)
    } else {
        (1 + rng.range_usize(0, 8), rng.range_usize(0, 4))
    };
    Cfg {
        m,
        n,
        nnz,
        block_size,
        chunk_elems,
        p_store,
        store_kind,
        p_load,
        load_kind,
    }
}

/// Kind index → concrete mapping. 2D grids use the most-square split
/// ([`Block2d::regular_auto`] — same rule the CLI applies).
fn build_mapping(kind: usize, m: u64, n: u64, p: usize) -> Arc<dyn ProcessMapping> {
    match kind {
        0 => Arc::new(Rowwise::regular(m, n, p)),
        1 => Arc::new(Colwise::regular(m, n, p)),
        2 => Arc::new(Block2d::regular_auto(m, n, p)),
        _ => Arc::new(CyclicRows { m, n, p }),
    }
}

/// Unique random global elements; values never 0.0 (a stored zero would
/// legitimately vanish through the dense scheme).
fn random_elements(rng: &mut Xoshiro256, m: u64, n: u64, nnz: usize) -> Vec<(u64, u64, f64)> {
    let mut seen = HashSet::new();
    let mut elems = Vec::with_capacity(nnz);
    while elems.len() < nnz {
        let i = rng.next_below(m);
        let j = rng.next_below(n);
        if seen.insert((i, j)) {
            let mag = rng.range_f64(0.1, 10.0);
            elems.push((i, j, if rng.chance(0.5) { -mag } else { mag }));
        }
    }
    elems
}

/// Partition global elements into per-rank local parts, with the same
/// windowing rule the storer uses (declared window for contiguous
/// mappings, tight bounding box for whole-matrix declarations).
fn parts_for(
    mapping: &dyn ProcessMapping,
    m: u64,
    n: u64,
    elems: &[(u64, u64, f64)],
) -> Vec<Coo> {
    let p = mapping.nprocs();
    let mut per: Vec<Vec<(u64, u64, f64)>> = vec![Vec::new(); p];
    for &(i, j, v) in elems {
        per[mapping.owner(i, j)].push((i, j, v));
    }
    let z = elems.len() as u64;
    (0..p)
        .map(|k| {
            let (ro, co, ml, nl) = mapping.window(k);
            let full = ro == 0 && co == 0 && ml == m && nl == n;
            let (ro, co, ml, nl) = if full && !per[k].is_empty() {
                tight_window(&per[k]).unwrap()
            } else {
                (ro, co, ml, nl)
            };
            let info = LocalInfo {
                m,
                n,
                z,
                m_local: ml,
                n_local: nl,
                z_local: 0,
                m_offset: ro,
                n_offset: co,
            };
            let mut coo = Coo::with_info(info);
            for &(i, j, v) in &per[k] {
                coo.push(i - ro, j - co, v);
            }
            coo
        })
        .collect()
}

/// Sorted global element list of loaded parts.
fn collect(mats: &[LoadedMatrix]) -> Vec<(u64, u64, f64)> {
    let mut out = Vec::new();
    for lm in mats {
        let coo = lm.clone().into_coo();
        let (ro, co) = (coo.info.m_offset, coo.info.n_offset);
        for (i, j, v) in coo.iter() {
            out.push((i + ro, j + co, v));
        }
    }
    out.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    out
}

#[test]
fn all_strategies_agree_on_random_configurations() {
    let seed = master_seed();
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let root = std::env::temp_dir().join(format!("abhsf-differential-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    for idx in 0..CONFIGS {
        let cfg = draw_cfg(&mut rng, idx);
        let ctx = format!("[reproduce: ABHSF_DIFF_SEED={seed} config #{idx} {cfg:?}]");
        let mut truth = random_elements(&mut rng, cfg.m, cfg.n, cfg.nnz);
        truth.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));

        let store_map = build_mapping(cfg.store_kind, cfg.m, cfg.n, cfg.p_store);
        let parts = parts_for(store_map.as_ref(), cfg.m, cfg.n, &truth);
        let dir = root.join(format!("cfg-{idx}"));
        let storage = storage_for(idx);
        storage.create_dir_all(&dir).unwrap();
        let store_cluster = Cluster::new(cfg.p_store, 64);
        let (dataset, sreport) = Dataset::store_parts_on(
            storage,
            &store_cluster,
            parts,
            &store_map,
            &dir,
            StoreOptions {
                block_size: cfg.block_size,
                chunk_elems: cfg.chunk_elems,
                ..Default::default()
            },
        )
        .unwrap_or_else(|e| panic!("store failed: {e} {ctx}"));
        assert_eq!(sreport.total_nnz() as usize, cfg.nnz, "{ctx}");

        let load_map = build_mapping(cfg.load_kind, cfg.m, cfg.n, cfg.p_load);
        let cluster = Cluster::new(cfg.p_load, 8);

        // Same-config fast path where applicable (Auto must take it).
        if cfg.p_load == cfg.p_store
            && load_map.descriptor().same_mapping(&store_map.descriptor())
        {
            let (mats, report) = dataset
                .load()
                .format(InMemFormat::Csr)
                .run(&cluster)
                .unwrap_or_else(|e| panic!("same-config failed: {e} {ctx}"));
            assert_eq!(report.scenario, "same-config", "{ctx}");
            assert_eq!(report.total_nnz() as usize, cfg.nnz, "{ctx}");
            assert_eq!(collect(&mats), truth, "same-config diverged {ctx}");
        }

        // All-read-all, pruned and unpruned, both I/O strategies.
        for strategy in [Strategy::Independent, Strategy::Collective] {
            for prune in [true, false] {
                let format = if prune { InMemFormat::Csr } else { InMemFormat::Coo };
                let (mats, report) = dataset
                    .load()
                    .mapping(&load_map)
                    .strategy(strategy)
                    .prune(prune)
                    .format(format)
                    .run(&cluster)
                    .unwrap_or_else(|e| panic!("{strategy} prune={prune} failed: {e} {ctx}"));
                assert_eq!(
                    report.total_nnz() as usize,
                    cfg.nnz,
                    "{strategy} prune={prune} nnz {ctx}"
                );
                assert_eq!(collect(&mats), truth, "{strategy} prune={prune} diverged {ctx}");
                if !prune {
                    assert_eq!(report.blocks_total(), 0, "{ctx}");
                }
            }
        }

        // Exchange loader.
        let (mats, report) = dataset
            .load()
            .mapping(&load_map)
            .strategy(Strategy::Exchange)
            .format(InMemFormat::Csr)
            .run(&cluster)
            .unwrap_or_else(|e| panic!("exchange failed: {e} {ctx}"));
        assert_eq!(report.total_nnz() as usize, cfg.nnz, "exchange nnz {ctx}");
        assert_eq!(collect(&mats), truth, "exchange diverged {ctx}");
        let opens: u64 = report.per_rank_io.iter().map(|s| s.opens).sum();
        assert_eq!(
            opens as usize,
            cfg.p_store,
            "exchange must open every file exactly once {ctx}"
        );

        // Kernel dimension: the cached reader's per-scheme block kernels
        // reproduce the truth product on every drawn configuration, and
        // the same query on two fresh caches is bit-identical with
        // identical miss counts.
        let x: Vec<f64> = (0..cfg.n).map(|j| 1.0 + (j % 5) as f64 * 0.5).collect();
        let mut want = vec![0.0; cfg.m as usize];
        for &(i, j, v) in &truth {
            want[i as usize] += v * x[j as usize];
        }
        let ca = BlockCache::with_budget(64 << 20);
        let cb = BlockCache::with_budget(64 << 20);
        let ya = dataset
            .reader(&ca)
            .and_then(|r| r.spmv(&x))
            .unwrap_or_else(|e| panic!("kernel spmv failed: {e} {ctx}"));
        let yb = dataset
            .reader(&cb)
            .and_then(|r| r.spmv(&x))
            .unwrap_or_else(|e| panic!("kernel spmv failed: {e} {ctx}"));
        assert!(
            abhsf::spmv::max_abs_diff(&ya, &want) < 1e-9,
            "kernel spmv diverged from truth {ctx}"
        );
        assert_eq!(ya, yb, "kernel spmv not deterministic {ctx}");
        assert_eq!(ca.stats().misses, cb.stats().misses, "miss counts diverged {ctx}");
        assert!(ca.stats().misses > 0, "spmv decoded no blocks {ctx}");

        let _ = std::fs::remove_dir_all(&dir);
    }
    let _ = std::fs::remove_dir_all(&root);
}

/// Repack round-trip property: for ~10 seeded configurations,
/// `load(repack(D, cfg'), any_strategy)` is element-identical to
/// `load(D)`, the repacked manifest's per-file nnz sum to the original
/// count, and no target rank ever stages more than its own region's
/// elements. Config #0 is pinned to the acceptance shape (Rowwise P=4 →
/// Block2d P=6, new block size) so the pruned read phase provably skips
/// blocks under every master seed.
#[test]
fn repack_roundtrip_is_element_identical() {
    const REPACK_CONFIGS: usize = 10;
    let seed = master_seed();
    let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x9E37_79B9_7F4A_7C15);
    let root = std::env::temp_dir().join(format!(
        "abhsf-repack-differential-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let mut total_skipped = 0u64;
    for idx in 0..REPACK_CONFIGS {
        // (m, n, nnz, s_store, chunk_store, p_store, store_kind,
        //  p_new, new_kind, s_new, chunk_new, p_load, load_kind)
        let cfg = if idx == 0 {
            (32, 32, 256, 4, 128, 4, 0, 6, 2, 8, 128, 5, 1)
        } else {
            let m = 8 + rng.next_below(73);
            let n = 8 + rng.next_below(73);
            let density = 0.02 + rng.next_f64() * 0.25;
            let nnz = (((m * n) as f64 * density) as usize).clamp(1, (m * n) as usize);
            (
                m,
                n,
                nnz,
                [2u64, 3, 4, 8, 16][rng.range_usize(0, 5)],
                [16u64, 128, 65536][rng.range_usize(0, 3)],
                1 + rng.range_usize(0, 5),
                rng.range_usize(0, 4),
                1 + rng.range_usize(0, 6),
                rng.range_usize(0, 4),
                [2u64, 3, 4, 8, 16][rng.range_usize(0, 5)],
                [16u64, 128, 65536][rng.range_usize(0, 3)],
                1 + rng.range_usize(0, 6),
                rng.range_usize(0, 4),
            )
        };
        let (m, n, nnz, s1, chunk1, p_store, store_kind) =
            (cfg.0, cfg.1, cfg.2, cfg.3, cfg.4, cfg.5, cfg.6);
        let (p_new, new_kind, s2, chunk2, p_load, load_kind) =
            (cfg.7, cfg.8, cfg.9, cfg.10, cfg.11, cfg.12);
        let ctx = format!(
            "[reproduce: ABHSF_DIFF_SEED={seed} repack config #{idx}: {m}x{n} nnz={nnz} \
             s {s1}->{s2} chunks {chunk1}->{chunk2} store P={p_store}/kind{store_kind} \
             -> P={p_new}/kind{new_kind}, load P={p_load}/kind{load_kind}]"
        );
        let mut truth = random_elements(&mut rng, m, n, nnz);
        truth.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));

        let store_map = build_mapping(store_kind, m, n, p_store);
        let parts = parts_for(store_map.as_ref(), m, n, &truth);
        let dir = root.join(format!("src-{idx}"));
        let storage = storage_for(idx);
        storage.create_dir_all(&dir).unwrap();
        let store_cluster = Cluster::new(p_store, 64);
        let (dataset, _) = Dataset::store_parts_on(
            Arc::clone(&storage),
            &store_cluster,
            parts,
            &store_map,
            &dir,
            StoreOptions {
                block_size: s1,
                chunk_elems: chunk1,
                ..Default::default()
            },
        )
        .unwrap_or_else(|e| panic!("store failed: {e} {ctx}"));

        // Repack to the new configuration.
        let new_map = build_mapping(new_kind, m, n, p_new);
        let out = root.join(format!("out-{idx}"));
        let repack_cluster = Cluster::new(p_new, 8);
        // Pin a small staging chunk so the memory bound below is a real,
        // falsifiable property of the re-bucketer, not the default mode.
        const STAGING_CHUNK: usize = 257;
        let (repacked, report) = dataset
            .repack()
            .nprocs(p_new)
            .mapping(&new_map)
            .block_size(s2)
            .chunk_elems(chunk2)
            .staging_chunk(STAGING_CHUNK)
            .run(&repack_cluster, &out)
            .unwrap_or_else(|e| panic!("repack failed: {e} {ctx}"));
        total_skipped += report.blocks_skipped();
        if idx == 0 {
            assert!(report.blocks_skipped() > 0, "pinned config must prune {ctx}");
        }
        assert_eq!(report.total_nnz() as usize, nnz, "repack nnz {ctx}");
        let manifest_nnz: u64 = repacked.manifest().files.iter().map(|f| f.nnz).sum();
        assert_eq!(manifest_nnz as usize, nnz, "manifest nnz sum {ctx}");
        assert_eq!(repacked.block_size(), s2, "{ctx}");
        assert_eq!(repacked.nprocs(), p_new, "{ctx}");
        // The falsifiable staging bound: with chunked accumulation the
        // unsorted working set never exceeds the pinned chunk.
        assert!(
            report.max_peak_unsorted() as usize <= STAGING_CHUNK,
            "unsorted staging {} exceeded chunk {STAGING_CHUNK} {ctx}",
            report.max_peak_unsorted()
        );
        // Bookkeeping: the resident set per rank is its own share (no
        // rank ever gathers the whole matrix).
        assert_eq!(
            report.max_peak_staging(),
            report.per_rank_nnz.iter().copied().max().unwrap_or(0),
            "staging exceeded the rank regions {ctx}"
        );

        // Reopen from the backend and read back through every strategy.
        let reopened = Dataset::open_on(Arc::clone(&storage), &out)
            .unwrap_or_else(|e| panic!("reopen: {e} {ctx}"));
        let same_cluster = Cluster::new(p_new, 8);
        let (mats, rep) = reopened
            .load()
            .format(InMemFormat::Csr)
            .run(&same_cluster)
            .unwrap_or_else(|e| panic!("same-config after repack: {e} {ctx}"));
        assert_eq!(rep.scenario, "same-config", "{ctx}");
        assert_eq!(collect(&mats), truth, "same-config diverged after repack {ctx}");

        let load_map = build_mapping(load_kind, m, n, p_load);
        let load_cluster = Cluster::new(p_load, 8);
        for strategy in [Strategy::Independent, Strategy::Collective, Strategy::Exchange] {
            let (mats, _) = reopened
                .load()
                .mapping(&load_map)
                .strategy(strategy)
                .format(InMemFormat::Coo)
                .run(&load_cluster)
                .unwrap_or_else(|e| panic!("{strategy} after repack: {e} {ctx}"));
            assert_eq!(collect(&mats), truth, "{strategy} diverged after repack {ctx}");
        }

        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&out);
    }
    assert!(total_skipped > 0, "no repack pruning observed");
    let _ = std::fs::remove_dir_all(&root);
}

/// Exchange-loader stress: maximal backpressure (channel capacity 1, 8
/// loading ranks) over a dense-ish matrix. `send_draining` must keep the
/// all-to-all element routing deadlock-free; a watchdog fails the test
/// after 60 s instead of letting CI hang.
#[test]
fn exchange_survives_maximal_backpressure() {
    let (tx, rx) = std::sync::mpsc::channel();
    let worker = std::thread::spawn(move || {
        let mut rng = Xoshiro256::seed_from_u64(master_seed() ^ 0xBACC);
        // Dense enough that every (reader, destination) pair exceeds the
        // loader's 4096-element batch: readers must send mid-stream while
        // their own inboxes are filling — the routing-cycle worst case.
        let (m, n) = (512u64, 512u64);
        let nnz = (m * n) as usize * 55 / 100;
        let mut truth = random_elements(&mut rng, m, n, nnz);
        truth.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let p_store = 4;
        let store_map: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(m, n, p_store));
        let parts = parts_for(store_map.as_ref(), m, n, &truth);
        let dir = std::env::temp_dir().join(format!(
            "abhsf-exchange-stress-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        // The property under stress is channel routing, not disk I/O:
        // the dense-ish store runs in memory so the 60 s watchdog budget
        // is spent on the exchange itself.
        let store_cluster = Cluster::new(p_store, 64);
        let (dataset, _) = Dataset::store_parts_on(
            Arc::new(MemFs::new()),
            &store_cluster,
            parts,
            &store_map,
            &dir,
            StoreOptions {
                block_size: 8,
                ..Default::default()
            },
        )
        .unwrap();
        let p_load = 8;
        let load_map: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(m, n, p_load));
        // channel_capacity = 1: every send beyond the first blocks until
        // the receiver drains — the worst case for a routing cycle.
        let cluster = Cluster::new(p_load, 1);
        let (mats, report) = dataset
            .load()
            .mapping(&load_map)
            .strategy(Strategy::Exchange)
            .format(InMemFormat::Coo)
            .run(&cluster)
            .unwrap();
        assert_eq!(report.total_nnz() as usize, nnz);
        assert_eq!(collect(&mats), truth);
        // The property under test is deadlock-free *termination* with
        // correct content; blocked time is scheduler-dependent and may
        // legitimately be zero when receivers drain fast enough.
        let _ = std::fs::remove_dir_all(&dir);
        tx.send(()).unwrap();
    });
    match rx.recv_timeout(std::time::Duration::from_secs(60)) {
        // Completed (or panicked — join propagates the worker's message).
        Ok(()) => worker.join().expect("stress worker panicked"),
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
            worker.join().expect("stress worker panicked");
        }
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => panic!(
            "exchange load did not complete within 60s under channel capacity 1 \
             — probable deadlock in send_draining"
        ),
    }
}
