//! Distributed SpMV engine + solver integration tests.
//!
//! Everything runs under **channel capacity 1** — every send beyond the
//! first blocks until the receiver drains, the worst case for the halo
//! exchange — and under a 60 s watchdog so a protocol deadlock fails CI
//! instead of hanging it. The core property: the distributed `y`
//! (owned segments concatenated in rank order) is **bit-identical** to
//! the single-rank [`SpmvParts`] result on every mapping, and the
//! engine's measured halo byte counters match [`predict_spmv_comm`]
//! exactly for rectangular mappings (upper bound for cyclic, whose
//! stored windows tighten to actual elements).

use std::sync::Arc;

use abhsf::cache::BlockCache;
use abhsf::coordinator::{Cluster, Dataset, StoreOptions};
use abhsf::dist::solvers::{conjugate_gradient, lanczos, power_iteration};
use abhsf::dist::{
    predict_spmv_comm, spmv_partitions, BlockOperator, CsrOperator, DistStats, LocalOperator,
    RankEngine,
};
use abhsf::formats::element::window_or_tight;
use abhsf::formats::{Coo, Csr, LocalInfo};
use abhsf::gen::{spd_parts, KroneckerGen, SeedMatrix};
use abhsf::mapping::{Block2d, Colwise, CyclicRows, MappingDesc, ProcessMapping, Rowwise};
use abhsf::spmv::SpmvParts;
use abhsf::util::rng::Xoshiro256;
use abhsf::vfs::MemFs;

/// Run `body` under a 60 s deadline; a hang is a halo-exchange deadlock.
fn with_watchdog(name: &'static str, body: impl FnOnce() + Send + 'static) {
    let (tx, rx) = std::sync::mpsc::channel();
    let worker = std::thread::spawn(move || {
        body();
        tx.send(()).unwrap();
    });
    match rx.recv_timeout(std::time::Duration::from_secs(60)) {
        Ok(()) => worker.join().expect("worker panicked"),
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
            worker.join().expect("worker panicked");
        }
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => panic!(
            "{name} did not finish within 60s under channel capacity 1 — \
             probable deadlock in the halo exchange"
        ),
    }
}

/// Random global elements with no duplicate coordinates.
fn random_elements(rng: &mut Xoshiro256, m: u64, n: u64, nnz: usize) -> Vec<(u64, u64, f64)> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::with_capacity(nnz);
    while out.len() < nnz {
        let i = rng.next_below(m);
        let j = rng.next_below(n);
        if seen.insert((i, j)) {
            out.push((i, j, rng.next_f64() * 2.0 - 1.0));
        }
    }
    out
}

/// Partition global elements into per-rank CSR parts exactly the way the
/// storer does: owner by the mapping, window kept when declared (rect
/// mappings) and tightened when it spans the whole matrix (cyclic).
fn parts_under(mapping: &dyn ProcessMapping, m: u64, n: u64, elems: &[(u64, u64, f64)]) -> Vec<Csr> {
    let p = mapping.nprocs();
    let mut per_rank: Vec<Vec<(u64, u64, f64)>> = vec![Vec::new(); p];
    for &(i, j, v) in elems {
        per_rank[mapping.owner(i, j)].push((i, j, v));
    }
    let total = elems.len() as u64;
    per_rank
        .into_iter()
        .enumerate()
        .map(|(rank, local)| {
            let declared = ProcessMapping::window(mapping, rank);
            let (ro, co, ml, nl) = window_or_tight(declared, m, n, &local);
            let info = LocalInfo {
                m,
                n,
                z: total,
                m_local: ml,
                n_local: nl,
                z_local: 0,
                m_offset: ro,
                n_offset: co,
            };
            let mut coo = Coo::with_info(info);
            for (i, j, v) in local {
                coo.push(i - ro, j - co, v);
            }
            Csr::from_coo(&coo)
        })
        .collect()
}

/// One distributed SpMV of `x` over `parts` under `desc`, channel
/// capacity 1: returns the concatenated `y` and the per-rank stats.
fn dist_spmv(
    desc: &MappingDesc,
    parts: &Arc<Vec<Csr>>,
    x: &Arc<Vec<f64>>,
    m: u64,
    n: u64,
) -> (Vec<f64>, Vec<DistStats>) {
    let p = desc.nprocs();
    let cluster = Cluster::new(p, 1);
    let desc = desc.clone();
    let parts = Arc::clone(parts);
    let x = Arc::clone(x);
    let out = cluster.run(move |ctx| {
        let (xp, yp) = spmv_partitions(&desc, m, n);
        let mut op = CsrOperator::new(std::slice::from_ref(&parts[ctx.rank]));
        let mut engine = RankEngine::new(ctx, xp, yp, op.row_window(), op.col_window());
        let (x0, x1) = engine.x_owned_range();
        let x_local = &x[x0 as usize..x1 as usize];
        let (y0, y1) = engine.y_owned_range();
        let mut y_local = vec![0.0f64; (y1 - y0) as usize];
        engine
            .spmv(&mut op, x_local, &mut y_local)
            .expect("CSR operator cannot fail");
        (y_local, engine.stats().clone())
    });
    let mut y = Vec::with_capacity(m as usize);
    let mut stats = Vec::with_capacity(p);
    for (y_local, s) in out {
        y.extend_from_slice(&y_local);
        stats.push(s);
    }
    (y, stats)
}

fn assert_bitwise_eq(got: &[f64], want: &[f64], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{ctx}: y[{i}] differs, {g:e} vs {w:e}"
        );
    }
}

/// Tentpole acceptance: P = 8, channel capacity 1, every mapping kind —
/// the distributed result is bit-identical to the single-rank kernel,
/// and the measured halo bytes match the comm model (exactly for rect
/// mappings, as an upper bound for the irregular cyclic fallback).
#[test]
fn distributed_spmv_bitwise_matches_single_rank_all_mappings() {
    with_watchdog("distributed spmv over all mappings", || {
        let (m, n, p) = (48u64, 48u64, 8usize);
        let mut rng = Xoshiro256::seed_from_u64(0xD157_2026);
        let elems = random_elements(&mut rng, m, n, (m * n) as usize / 5);
        let x: Arc<Vec<f64>> =
            Arc::new((0..n).map(|i| 0.5 + ((i % 7) as f64) * 0.25).collect());
        let mappings: Vec<(&str, Arc<dyn ProcessMapping>)> = vec![
            ("rowwise", Arc::new(Rowwise::regular(m, n, p))),
            ("colwise", Arc::new(Colwise::regular(m, n, p))),
            ("2d", Arc::new(Block2d::regular(m, n, 2, 4))),
            ("cyclic", Arc::new(CyclicRows { m, n, p })),
        ];
        for (label, mapping) in mappings {
            let parts = Arc::new(parts_under(mapping.as_ref(), m, n, &elems));
            let desc = mapping.descriptor();
            let want = SpmvParts::Csr(&parts).spmv(&x);
            let (got, stats) = dist_spmv(&desc, &parts, &x, m, n);
            assert_bitwise_eq(&got, &want, label);

            let pred = predict_spmv_comm(&desc, m, n);
            for (k, s) in stats.iter().enumerate() {
                if pred.exact {
                    assert_eq!(
                        s.halo_bytes_sent, pred.per_rank_sent[k],
                        "{label}: rank {k} sent bytes != prediction"
                    );
                    assert_eq!(
                        s.halo_bytes_recv, pred.per_rank_recv[k],
                        "{label}: rank {k} recv bytes != prediction"
                    );
                } else {
                    assert!(
                        s.halo_bytes_sent <= pred.per_rank_sent[k]
                            && s.halo_bytes_recv <= pred.per_rank_recv[k],
                        "{label}: rank {k} exceeded the upper-bound prediction"
                    );
                }
            }
            assert_eq!(pred.exact, label != "cyclic", "{label}: exactness flag");
        }
    });
}

/// CG on a generated SPD system at P = 8 converges to 1e-8, the
/// solution satisfies the resident operator to the same tolerance, and
/// the halo traffic stays strictly below the P × full-vector broadcast.
#[test]
fn cg_converges_on_generated_spd_at_p8() {
    with_watchdog("distributed CG", || {
        let gen = KroneckerGen::new(SeedMatrix::cage_like(8, 42), 2);
        let n = gen.dim();
        let p = 8usize;
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p));
        let (coo_parts, _sigma) = spd_parts(&gen, mapping.as_ref(), 0.0);
        let parts: Arc<Vec<Csr>> =
            Arc::new(coo_parts.iter().map(Csr::from_coo).collect());
        let desc = mapping.descriptor();
        let b: Arc<Vec<f64>> =
            Arc::new((0..n).map(|i| 1.0 + ((i % 17) as f64) * 0.25).collect());
        let tol = 1e-8;

        let cluster = Cluster::new(p, 1);
        let run_desc = desc.clone();
        let run_parts = Arc::clone(&parts);
        let run_b = Arc::clone(&b);
        let out = cluster.run(move |ctx| {
            let (xp, yp) = spmv_partitions(&run_desc, n, n);
            let mut op = CsrOperator::new(std::slice::from_ref(&run_parts[ctx.rank]));
            let mut engine = RankEngine::new(ctx, xp, yp, op.row_window(), op.col_window());
            let (y0, y1) = engine.y_owned_range();
            let outcome = conjugate_gradient(
                &mut engine,
                &mut op,
                &run_b[y0 as usize..y1 as usize],
                tol,
                500,
            )
            .expect("CSR operator cannot fail");
            (outcome, engine.stats().clone())
        });

        let outcome = &out[0].0;
        assert!(
            outcome.converged,
            "CG did not converge: residuals {:?}",
            outcome.residuals
        );
        // All ranks iterate on identical bits (allreduce determinism).
        for (o, _) in &out {
            assert_eq!(o.iterations, outcome.iterations);
            assert_eq!(o.value.to_bits(), outcome.value.to_bits());
        }
        // Resident cross-check: ‖b − S x‖ under the single-rank kernel.
        let x: Vec<f64> = out.iter().flat_map(|(o, _)| o.x_local.clone()).collect();
        let sx = SpmvParts::Csr(&parts).spmv(&x);
        let resid = b
            .iter()
            .zip(&sx)
            .map(|(bi, yi)| (bi - yi) * (bi - yi))
            .sum::<f64>()
            .sqrt();
        let bnorm = b.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(
            resid <= 10.0 * tol * bnorm.max(1.0),
            "resident residual {resid:e} vs tol {tol:e} (‖b‖ = {bnorm:e})"
        );
        // Strictly below the naive broadcast.
        let pred = predict_spmv_comm(&desc, n, n);
        let spmvs: u64 = out[0].1.spmvs;
        assert!(spmvs > 0);
        let sent_per_spmv: u64 =
            out.iter().map(|(_, s)| s.halo_bytes_sent).sum::<u64>() / spmvs;
        assert!(
            sent_per_spmv < pred.broadcast_bytes,
            "halo {sent_per_spmv} B/spmv not below broadcast {} B",
            pred.broadcast_bytes
        );
    });
}

/// Lanczos Ritz values bracket a positive spectrum on the SPD operand
/// and λ_max agrees with converged power iteration.
#[test]
fn lanczos_extremal_estimates_match_power_iteration() {
    with_watchdog("distributed Lanczos", || {
        let gen = KroneckerGen::new(SeedMatrix::cage_like(6, 7), 2);
        let n = gen.dim();
        let p = 4usize;
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p));
        let (coo_parts, _) = spd_parts(&gen, mapping.as_ref(), 0.0);
        let parts: Arc<Vec<Csr>> =
            Arc::new(coo_parts.iter().map(Csr::from_coo).collect());
        let desc = mapping.descriptor();

        let cluster = Cluster::new(p, 1);
        let run_parts = Arc::clone(&parts);
        let out = cluster.run(move |ctx| {
            let (xp, yp) = spmv_partitions(&desc, n, n);
            let mut op = CsrOperator::new(std::slice::from_ref(&run_parts[ctx.rank]));
            let mut engine = RankEngine::new(ctx, xp, yp, op.row_window(), op.col_window());
            let lz = lanczos(&mut engine, &mut op, 40).expect("CSR operator cannot fail");
            let pw = power_iteration(&mut engine, &mut op, 1e-12, 2000)
                .expect("CSR operator cannot fail");
            (lz, pw)
        });
        let (lz, pw) = &out[0];
        let (lmin, lmax) = lz.extremal.expect("lanczos reports extremal estimates");
        assert!(lz.converged);
        assert!(
            0.0 < lmin && lmin <= lmax,
            "SPD spectrum must be positive: ({lmin}, {lmax})"
        );
        assert!(pw.converged, "power iteration did not settle");
        let rel = ((lmax - pw.value) / pw.value).abs();
        assert!(
            rel < 1e-3,
            "λ_max {lmax:e} vs power estimate {:e} (rel {rel:e})",
            pw.value
        );
    });
}

/// Block mode: the engine applying straight from decoded ABHSF blocks
/// (read-ahead pipeline, per-scheme kernels) on a rowwise-stored
/// dataset is bit-identical to the resident cached-reader SpMV.
#[test]
fn block_operator_matches_reader_spmv_bitwise() {
    with_watchdog("distributed block-mode spmv", || {
        let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 42), 2));
        let n = gen.dim();
        let p = 4usize;
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p));
        let dir = std::path::PathBuf::from("dist-block-mode");
        let storage = Arc::new(MemFs::new());
        let store_cluster = Cluster::new(p, 64);
        let (dataset, _) = Dataset::store_on(
            storage,
            &store_cluster,
            &gen,
            &mapping,
            &dir,
            StoreOptions {
                block_size: 8,
                ..Default::default()
            },
        )
        .expect("in-memory store");
        let desc = dataset.mapping().clone();
        let x: Arc<Vec<f64>> =
            Arc::new((0..n).map(|i| 0.25 + ((i % 13) as f64) * 0.5).collect());

        let cache = Arc::new(BlockCache::with_budget(64 << 20));
        let want = dataset
            .reader(&cache)
            .expect("reader")
            .spmv(&x)
            .expect("resident reader spmv");

        let cluster = Cluster::new(p, 1);
        let ds = dataset.clone();
        let run_x = Arc::clone(&x);
        let run_cache = Arc::clone(&cache);
        let out = cluster.run(move |ctx| {
            let reader = ds.reader(&run_cache).expect("per-rank reader");
            let mut op = BlockOperator::new(&reader, ctx.rank);
            let (xp, yp) = spmv_partitions(&desc, n, n);
            let mut engine = RankEngine::new(ctx, xp, yp, op.row_window(), op.col_window());
            let (x0, x1) = engine.x_owned_range();
            let (y0, y1) = engine.y_owned_range();
            let mut y_local = vec![0.0f64; (y1 - y0) as usize];
            engine
                .spmv(&mut op, &run_x[x0 as usize..x1 as usize], &mut y_local)
                .expect("block fetch over MemFs");
            y_local
        });
        let got: Vec<f64> = out.into_iter().flatten().collect();
        assert_bitwise_eq(&got, &want, "block mode vs cached reader");
    });
}

/// The partitioning contract the solvers rely on: square matrices give
/// x-partition == y-partition under every mapping kind.
#[test]
fn square_partitions_align_for_solvers() {
    let (m, n, p) = (40u64, 40u64, 8usize);
    let descs: Vec<MappingDesc> = vec![
        Rowwise::regular(m, n, p).descriptor(),
        Colwise::regular(m, n, p).descriptor(),
        Block2d::regular(m, n, 2, 4).descriptor(),
        CyclicRows { m, n, p }.descriptor(),
    ];
    for desc in descs {
        let (xp, yp) = spmv_partitions(&desc, m, n);
        assert_eq!(xp, yp, "{}: square x/y partitions must align", desc.kind());
    }
}
