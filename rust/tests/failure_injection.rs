//! Failure injection: corrupted, truncated and mismatched files must be
//! *detected* (clean errors), never silently mis-loaded or crash.

use std::io::{Read, Seek, SeekFrom, Write};
use std::sync::Arc;

use abhsf::abhsf::{load_csr, matrix_file_path};
use abhsf::coordinator::{Cluster, Dataset, DatasetError, StoreOptions};
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::h5::H5Reader;
use abhsf::mapping::ProcessMapping;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("abhsf-failure-tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Store a small matrix and return the directory.
fn store_one(name: &str) -> std::path::PathBuf {
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 5), 2));
    let mapping: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(1));
    let cluster = Cluster::new(1, 8);
    let dir = tmpdir(name);
    Dataset::store(
        &cluster,
        &gen,
        &mapping,
        &dir,
        StoreOptions {
            block_size: 8,
            ..Default::default()
        },
    )
    .unwrap();
    dir
}

#[test]
fn bit_flip_in_payload_detected_by_checksum() {
    let dir = store_one("bitflip");
    let path = matrix_file_path(&dir, 0);
    // Flip one byte in the middle of the data section.
    let mut f = std::fs::OpenOptions::new()
        .read(true)
        .write(true)
        .open(&path)
        .unwrap();
    let len = f.metadata().unwrap().len();
    f.seek(SeekFrom::Start(len / 3)).unwrap();
    let mut b = [0u8; 1];
    f.read_exact(&mut b).unwrap();
    f.seek(SeekFrom::Start(len / 3)).unwrap();
    f.write_all(&[b[0] ^ 0xFF]).unwrap();
    drop(f);

    match H5Reader::open(&path) {
        // Flip landed in the directory region: open itself must fail.
        Err(_) => {}
        Ok(r) => {
            let err = load_csr(&r).expect_err("corruption must be detected");
            let msg = format!("{err}");
            assert!(
                msg.contains("checksum") || msg.contains("Invalid") || msg.contains("invalid"),
                "unexpected error: {msg}"
            );
        }
    }
}

#[test]
fn truncated_file_detected() {
    let dir = store_one("truncate");
    let path = matrix_file_path(&dir, 0);
    let len = std::fs::metadata(&path).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
    f.set_len(len / 2).unwrap();
    drop(f);
    assert!(
        H5Reader::open(&path).is_err(),
        "truncated file must not open cleanly"
    );
}

#[test]
fn not_a_container_detected() {
    let dir = tmpdir("garbage");
    let path = dir.join("matrix-0.h5spm");
    std::fs::write(&path, b"this is not an h5spm container at all").unwrap();
    let Err(err) = H5Reader::open(&path) else {
        panic!("garbage file opened cleanly")
    };
    assert!(format!("{err}").contains("not an h5spm"), "{err}");
}

#[test]
fn unfinished_file_detected() {
    // A writer that never called finish() leaves dir_offset == 0.
    let dir = tmpdir("unfinished");
    let path = dir.join("matrix-0.h5spm");
    {
        let mut w = abhsf::h5::H5Writer::create(&path).unwrap();
        w.set_attr("m", 4u64).unwrap();
        w.write_dataset::<u8>("schemes", &[0]).unwrap();
        // Dropped without finish().
    }
    let Err(err) = H5Reader::open(&path) else {
        panic!("unfinished file opened cleanly")
    };
    assert!(format!("{err}").contains("unfinished"), "{err}");
}

#[test]
fn missing_dataset_is_clean_error() {
    let dir = tmpdir("missing-ds");
    let path = dir.join("matrix-0.h5spm");
    {
        let mut w = abhsf::h5::H5Writer::create(&path).unwrap();
        for name in ["m", "n", "z", "m_local", "n_local", "z_local", "m_offset", "n_offset"] {
            w.set_attr(name, 4u64).unwrap();
        }
        w.set_attr("block_size", 2u64).unwrap();
        w.set_attr("blocks", 1u64).unwrap();
        // Descriptor datasets present, payload datasets absent.
        w.write_dataset::<u8>("schemes", &[0]).unwrap();
        w.write_dataset::<u32>("zetas", &[1]).unwrap();
        w.write_dataset::<u32>("brows", &[0]).unwrap();
        w.write_dataset::<u32>("bcols", &[0]).unwrap();
        w.finish().unwrap();
    }
    let r = H5Reader::open(&path).unwrap();
    let err = load_csr(&r).expect_err("missing payload datasets");
    assert!(format!("{err}").contains("no such dataset"), "{err}");
}

#[test]
fn zeta_inconsistency_detected() {
    // Build a file whose zeta disagrees with the stored payload length.
    let dir = tmpdir("zeta");
    let path = dir.join("matrix-0.h5spm");
    {
        let mut w = abhsf::h5::H5Writer::create(&path).unwrap();
        for (name, v) in [
            ("m", 4u64),
            ("n", 4),
            ("z", 2),
            ("m_local", 4),
            ("n_local", 4),
            ("z_local", 2),
            ("m_offset", 0),
            ("n_offset", 0),
            ("block_size", 4),
            ("blocks", 1),
        ] {
            w.set_attr(name, v).unwrap();
        }
        w.write_dataset::<u8>("schemes", &[0]).unwrap(); // COO block
        w.write_dataset::<u32>("zetas", &[2]).unwrap(); // claims 2 elements
        w.write_dataset::<u32>("brows", &[0]).unwrap();
        w.write_dataset::<u32>("bcols", &[0]).unwrap();
        w.write_dataset::<u16>("coo_lrows", &[0]).unwrap(); // holds 1
        w.write_dataset::<u16>("coo_lcols", &[0]).unwrap();
        w.write_dataset::<f64>("coo_vals", &[1.0]).unwrap();
        for name in ["csr_lcolinds", "csr_rowptrs", "csr_vals"] {
            if name == "csr_rowptrs" {
                w.write_dataset::<u32>(name, &[]).unwrap();
            } else if name == "csr_vals" {
                w.write_dataset::<f64>(name, &[]).unwrap();
            } else {
                w.write_dataset::<u16>(name, &[]).unwrap();
            }
        }
        w.write_dataset::<u8>("bitmap_bitmap", &[]).unwrap();
        w.write_dataset::<f64>("bitmap_vals", &[]).unwrap();
        w.write_dataset::<f64>("dense_vals", &[]).unwrap();
        w.finish().unwrap();
    }
    let r = H5Reader::open(&path).unwrap();
    let err = load_csr(&r).expect_err("zeta inconsistency");
    let msg = format!("{err}");
    assert!(
        msg.contains("exhausted") || msg.contains("Invalid") || msg.contains("invalid"),
        "{msg}"
    );
}

#[test]
fn wrong_scheme_tag_detected() {
    // Valid container, invalid scheme tag (paper Algorithm 2's error arm).
    let dir = tmpdir("scheme-tag");
    let path = dir.join("matrix-0.h5spm");
    {
        let mut w = abhsf::h5::H5Writer::create(&path).unwrap();
        for (name, v) in [
            ("m", 4u64),
            ("n", 4),
            ("z", 1),
            ("m_local", 4),
            ("n_local", 4),
            ("z_local", 1),
            ("m_offset", 0),
            ("n_offset", 0),
            ("block_size", 4),
            ("blocks", 1),
        ] {
            w.set_attr(name, v).unwrap();
        }
        w.write_dataset::<u8>("schemes", &[9]).unwrap(); // bogus tag
        w.write_dataset::<u32>("zetas", &[1]).unwrap();
        w.write_dataset::<u32>("brows", &[0]).unwrap();
        w.write_dataset::<u32>("bcols", &[0]).unwrap();
        w.write_dataset::<u16>("coo_lrows", &[0]).unwrap();
        w.write_dataset::<u16>("coo_lcols", &[0]).unwrap();
        w.write_dataset::<f64>("coo_vals", &[1.0]).unwrap();
        w.write_dataset::<u16>("csr_lcolinds", &[]).unwrap();
        w.write_dataset::<u32>("csr_rowptrs", &[]).unwrap();
        w.write_dataset::<f64>("csr_vals", &[]).unwrap();
        w.write_dataset::<u8>("bitmap_bitmap", &[]).unwrap();
        w.write_dataset::<f64>("bitmap_vals", &[]).unwrap();
        w.write_dataset::<f64>("dense_vals", &[]).unwrap();
        w.finish().unwrap();
    }
    let r = H5Reader::open(&path).unwrap();
    let err = load_csr(&r).expect_err("bad scheme tag");
    assert!(format!("{err}").contains("scheme tag"), "{err}");
}

#[test]
fn worker_error_propagates_not_hangs() {
    // A cluster/dataset size mismatch must surface as a typed error from
    // the planner (it used to run and fail rank-by-rank, or worse,
    // panic), and must not wedge the cluster.
    let dir = store_one("partial");
    // Ask for 3 ranks but only 1 file exists.
    let cluster = Cluster::new(3, 8);
    let err = Dataset::open(&dir)
        .unwrap()
        .load()
        .run(&cluster)
        .expect_err("p_load != p_store without a mapping must error");
    assert!(
        matches!(err, DatasetError::MappingRequired { nprocs: 3, stored: 1 }),
        "{err}"
    );
    // The cluster must remain usable for the next job.
    let ok = cluster.run(|ctx| ctx.rank);
    assert_eq!(ok, vec![0, 1, 2]);
}

#[test]
fn mid_load_worker_failure_propagates_not_hangs() {
    // A container that passes the up-front existence check but fails to
    // *open* inside a worker (truncated mid-write, say) must surface as
    // Err from the leader — while the other ranks' jobs complete — and
    // must not wedge the cluster for the next job.
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 5), 2));
    let mapping: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(3));
    let cluster = Cluster::new(3, 8);
    let dir = tmpdir("mid-load");
    let (dataset, _) =
        Dataset::store(&cluster, &gen, &mapping, &dir, Default::default()).unwrap();
    let path = matrix_file_path(&dir, 1);
    let len = std::fs::metadata(&path).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
    f.set_len(len / 2).unwrap();
    drop(f);

    let res = dataset.load().run(&cluster);
    assert!(res.is_err(), "truncated container must fail the load");
    // The cluster must remain usable for the next job.
    let ok = cluster.run(|ctx| ctx.rank);
    assert_eq!(ok, vec![0, 1, 2]);
}

#[test]
fn missing_stored_file_is_typed_error() {
    // Delete one container of a 2-file dataset: the plan must report a
    // MissingFile naming the path instead of treating it as 0 bytes.
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 5), 2));
    let mapping: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(2));
    let cluster = Cluster::new(2, 8);
    let dir = tmpdir("missing-file");
    let (dataset, _) =
        Dataset::store(&cluster, &gen, &mapping, &dir, Default::default()).unwrap();
    std::fs::remove_file(matrix_file_path(&dir, 1)).unwrap();
    let err = dataset
        .load()
        .run(&cluster)
        .expect_err("missing container must fail the plan");
    match err {
        DatasetError::MissingFile { path, .. } => {
            assert!(path.ends_with("matrix-1.h5spm"), "{}", path.display());
        }
        other => panic!("expected MissingFile, got {other}"),
    }
}

#[test]
fn corrupt_manifest_is_typed_error() {
    let dir = store_one("bad-manifest");
    std::fs::write(dir.join(abhsf::coordinator::MANIFEST_FILE), "{not json").unwrap();
    let err = Dataset::open(&dir).expect_err("garbage manifest must not open");
    assert!(matches!(err, DatasetError::BadManifest { .. }), "{err}");
}
