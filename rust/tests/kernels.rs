//! Differential harness for the per-scheme block SpMV kernels and the
//! measured-cost calibration path.
//!
//! The kernels' exactness contract (see `rust/src/spmv/kernels.rs`) is
//! that every scheme applies its elements to `y` one at a time in the
//! natural row-major decode order — the same stream the generic
//! `SpmvParts::Elements` path applies. That makes the per-scheme results
//! **bit-identical** to the generic path, so almost every comparison
//! here is `assert_eq!` on raw `f64` vectors, not a tolerance check.
//!
//! Where orders legitimately differ (the stored-order block walk versus
//! a globally sorted oracle), values are drawn as small dyadic rationals
//! (multiples of 1/4 below 2) whose f64 sums are exact in *any* order,
//! so those comparisons stay exact too.

use abhsf::abhsf::load::DecodedBlock;
use abhsf::abhsf::store::store_data_chunked_on;
use abhsf::abhsf::{
    fetch_decoded_blocks_batched, AbhsfData, BlockDirectory, CostModel, MeasuredCosts,
    MeasuredEntry, Scheme,
};
use abhsf::formats::{Coo, LocalInfo};
use abhsf::h5::H5Reader;
use abhsf::spmv::{kernels::spmv_block_into, SpmvParts};
use abhsf::util::json::Json;
use abhsf::util::rng::Xoshiro256;
use abhsf::vfs::MemFs;

type LocalElem = (u16, u16, f64);

/// A nonzero dyadic value in `±[0.25, 2]`: sums of any number (< 2^40)
/// of these are exact in f64 regardless of association order.
fn dyadic(rng: &mut Xoshiro256) -> f64 {
    let mag = (1 + rng.next_below(8)) as f64 * 0.25;
    if rng.chance(0.5) {
        -mag
    } else {
        mag
    }
}

/// `zeta` distinct random cells of an `s × s` block, strictly row-major
/// (the order [`DecodedBlock::build`] requires), with dyadic values.
fn random_cells(rng: &mut Xoshiro256, s: u64, zeta: u64) -> Vec<LocalElem> {
    let mut cells = rng.sample_indices((s * s) as usize, zeta as usize);
    cells.sort_unstable();
    cells
        .into_iter()
        .map(|cell| {
            let (lr, lc) = ((cell as u64 / s) as u16, (cell as u64 % s) as u16);
            (lr, lc, dyadic(rng))
        })
        .collect()
}

/// Per-scheme on-disk payload size in bytes under the default widths —
/// the independent formula [`DecodedBlock::payload_bytes`] must match.
fn expected_payload_bytes(scheme: Scheme, s: u64, zeta: u64) -> u64 {
    match scheme {
        Scheme::Coo => (2 + 2 + 8) * zeta,
        Scheme::Csr => 4 * (s + 1) + (2 + 8) * zeta,
        Scheme::Bitmap => (s * s).div_ceil(8) + 8 * zeta,
        Scheme::Dense => 8 * s * s,
    }
}

/// Run one block through the kernel, the `Blocks` variant, and the
/// generic `Elements` path, all from the same dirty `y`; every result
/// must be bit-identical.
fn assert_kernel_matches_generic(block: &DecodedBlock, x: &[f64], dirty: &[f64], ctx: &str) {
    let g = block.geom();
    let (m, n) = (dirty.len() as u64, x.len() as u64);
    assert!(g.row0 + g.s <= m && g.col0 + g.s <= n, "{ctx}: bad harness dims");

    let mut direct = dirty.to_vec();
    spmv_block_into(block, x, &mut direct);

    let one = [block];
    let mut via_blocks = dirty.to_vec();
    SpmvParts::Blocks { m, n, blocks: &one }.spmv_into(x, &mut via_blocks);

    let triplets = block.elements();
    assert_eq!(triplets.len() as u64, block.zeta(), "{ctx}: zeta mismatch");
    let slices = [triplets.as_slice()];
    let mut generic = dirty.to_vec();
    SpmvParts::Elements { m, n, parts: &slices }.spmv_into(x, &mut generic);

    assert_eq!(direct, generic, "{ctx}: kernel != Elements path");
    assert_eq!(via_blocks, direct, "{ctx}: Blocks variant != direct kernel");
}

/// Every scheme's kernel is bit-identical to the generic triplet path on
/// hand-picked edge geometries: empty block, fully dense, single
/// row/column, ζ = 1, non-power-of-two `s`, `s = 1` — each also placed
/// at a nonzero global offset so `row0`/`col0` handling is exercised.
#[test]
fn kernels_match_elements_path_on_edge_geometries() {
    let mut rng = Xoshiro256::seed_from_u64(0xED6E);
    let full = |s: u64| -> Vec<(u16, u16)> {
        (0..s * s)
            .map(|cell| ((cell / s) as u16, (cell % s) as u16))
            .collect()
    };
    // (label, s, cells): values are attached per scheme below.
    let cases: [(&str, u64, Vec<(u16, u16)>); 8] = [
        ("empty", 7, vec![]),
        ("fully-dense", 6, full(6)),
        ("single-row", 9, (0..9).map(|lc| (3u16, lc as u16)).collect()),
        ("single-col", 9, (0..9).map(|lr| (lr as u16, 4u16)).collect()),
        ("one-elem", 8, vec![(5, 2)]),
        ("non-pow2", 5, vec![(0, 4), (1, 1), (1, 2), (3, 0), (4, 4)]),
        ("s1-empty", 1, vec![]),
        ("s1-full", 1, vec![(0, 0)]),
    ];
    for (label, s, cells) in &cases {
        let s = *s;
        for (row0, col0) in [(0u64, 0u64), (2 * s, s)] {
            // Arbitrary (non-dyadic) values: same-order comparison is
            // exact by the kernels' summation-order contract alone. A
            // stored zero would legitimately vanish through the dense
            // scheme, so values stay away from 0.
            let elems: Vec<LocalElem> = cells
                .iter()
                .map(|&(lr, lc)| {
                    let sign = if lc % 2 == 0 { 1.0 } else { -1.0 };
                    (lr, lc, sign * rng.range_f64(0.5, 3.0))
                })
                .collect();
            let (m, n) = (row0 + s, col0 + s);
            let x: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
            let dirty: Vec<f64> = (0..m).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            for scheme in Scheme::ALL {
                let ctx = format!("{label} s={s} offset=({row0},{col0}) {scheme:?}");
                let block = DecodedBlock::build(scheme, row0, col0, s, &elems)
                    .unwrap_or_else(|e| panic!("{ctx}: build failed: {e}"));
                assert_eq!(block.scheme(), scheme, "{ctx}");
                assert_eq!(block.zeta() as usize, elems.len(), "{ctx}");
                assert_eq!(
                    block.payload_bytes(),
                    expected_payload_bytes(scheme, s, elems.len() as u64),
                    "{ctx}: payload bytes"
                );
                assert_kernel_matches_generic(&block, &x, &dirty, &ctx);
            }
        }
    }
}

/// Seeded random blocks: for every drawn (s, ζ) all four scheme
/// encodings produce bit-identical products from the same dirty `y`,
/// and (dyadic values) equal the order-independent dense oracle.
#[test]
fn kernels_agree_across_schemes_on_random_blocks() {
    let sizes = [1u64, 2, 3, 4, 5, 7, 8, 12, 16, 33];
    for seed in 0..12u64 {
        let mut rng = Xoshiro256::seed_from_u64(0x5EED_0000 + seed);
        let s = sizes[rng.range_usize(0, sizes.len())];
        let zeta = rng.next_below(s * s + 1);
        let elems = random_cells(&mut rng, s, zeta);
        let (row0, col0) = (rng.next_below(3) * s, rng.next_below(3) * s);
        let (m, n) = (row0 + s, col0 + s);
        let x: Vec<f64> = (0..n).map(|_| dyadic(&mut rng)).collect();
        let dirty: Vec<f64> = (0..m).map(|_| dyadic(&mut rng)).collect();
        let ctx = format!("seed={seed} s={s} zeta={zeta} offset=({row0},{col0})");

        // Order-independent oracle (exact: all terms dyadic).
        let mut oracle = dirty.clone();
        for &(lr, lc, v) in &elems {
            oracle[(row0 + lr as u64) as usize] += v * x[(col0 + lc as u64) as usize];
        }
        for scheme in Scheme::ALL {
            let block = DecodedBlock::build(scheme, row0, col0, s, &elems)
                .unwrap_or_else(|e| panic!("{ctx} {scheme:?}: build failed: {e}"));
            assert_kernel_matches_generic(&block, &x, &dirty, &format!("{ctx} {scheme:?}"));
            let mut y = dirty.clone();
            spmv_block_into(&block, &x, &mut y);
            assert_eq!(y, oracle, "{ctx} {scheme:?}: != dense oracle");
        }
    }
}

/// `SpmvParts::spmv_into` **accumulates** into the caller's `y` — it
/// never zeroes or overwrites — for every variant, and `spmv` is the
/// overwrite form. Pinned with a dirty, reused buffer: two consecutive
/// `spmv_into` calls add the product twice (all values dyadic, so the
/// expectation is exact).
#[test]
fn spmv_into_accumulates_into_dirty_y_for_every_variant() {
    // 6x6 global matrix, two row bands of 3.
    let entries: [(u64, u64, f64); 7] = [
        (0, 0, 2.0),
        (0, 5, 1.25),
        (1, 2, -0.75),
        (2, 4, 4.0),
        (3, 1, 0.5),
        (4, 4, -2.0),
        (5, 0, 1.5),
    ];
    let (m, n) = (6u64, 6u64);
    let mut coo_parts = Vec::new();
    for off in [0u64, 3] {
        let info = LocalInfo {
            m,
            n,
            z: entries.len() as u64,
            m_local: 3,
            n_local: n,
            z_local: 0,
            m_offset: off,
            n_offset: 0,
        };
        let mut coo = Coo::with_info(info);
        for &(i, j, v) in entries.iter().filter(|e| e.0 >= off && e.0 < off + 3) {
            coo.push(i - off, j, v);
        }
        coo_parts.push(coo);
    }
    let csr_parts: Vec<abhsf::formats::Csr> =
        coo_parts.iter().map(abhsf::formats::Csr::from_coo).collect();
    let triplets: Vec<Vec<(u64, u64, f64)>> = coo_parts
        .iter()
        .map(|p| {
            let ro = p.info.m_offset;
            p.iter().map(|(i, j, v)| (i + ro, j, v)).collect()
        })
        .collect();
    let slices: Vec<&[(u64, u64, f64)]> = triplets.iter().map(|t| t.as_slice()).collect();
    // Decoded 3x3 blocks over the 2x2 block grid (blocks are square, so
    // the column span must be split alongside the rows).
    let mut blocks: Vec<DecodedBlock> = Vec::new();
    for (brow, bcol) in [(0u64, 0u64), (0, 1), (1, 0), (1, 1)] {
        let (row0, col0) = (brow * 3, bcol * 3);
        let elems: Vec<LocalElem> = entries
            .iter()
            .filter(|e| e.0 >= row0 && e.0 < row0 + 3 && e.1 >= col0 && e.1 < col0 + 3)
            .map(|&(i, j, v)| ((i - row0) as u16, (j - col0) as u16, v))
            .collect();
        blocks.push(DecodedBlock::build(Scheme::Coo, row0, col0, 3, &elems).unwrap());
    }
    let block_refs: Vec<&DecodedBlock> = blocks.iter().collect();

    let x = [1.0, -2.0, 0.5, 3.0, 0.25, -1.5];
    let dirty = [0.5, -1.0, 2.0, 0.25, -0.75, 1.5];
    // Exact expected product (dyadic terms: order-independent).
    let mut ax = vec![0.0; m as usize];
    for &(i, j, v) in &entries {
        ax[i as usize] += v * x[j as usize];
    }

    let variants = [
        ("Csr", SpmvParts::Csr(&csr_parts)),
        ("Coo", SpmvParts::Coo(&coo_parts)),
        ("Elements", SpmvParts::Elements { m, n, parts: &slices }),
        ("Blocks", SpmvParts::Blocks { m, n, blocks: &block_refs }),
    ];
    for (label, parts) in &variants {
        // Overwrite form: zeroed allocation, exactly A·x.
        assert_eq!(parts.spmv(&x), ax, "[{label}] spmv != A·x");
        // Accumulate form: dirty y, applied twice, never cleared.
        let mut y = dirty.to_vec();
        parts.spmv_into(&x, &mut y);
        parts.spmv_into(&x, &mut y);
        let want: Vec<f64> = dirty.iter().zip(&ax).map(|(d, a)| d + 2.0 * a).collect();
        assert_eq!(y, want, "[{label}] spmv_into must accumulate, not overwrite");
    }
}

/// End-to-end: encode a random matrix into ABHSF (`AbhsfData::from_coo`),
/// store it into an h5spm container on the in-memory backend, decode it
/// back through the batched block pipeline, and prove the per-scheme
/// kernels reproduce the original matrix — elements, payload accounting,
/// and the SpMV product (exact: dyadic values).
#[test]
fn encode_decode_kernel_roundtrip_matches_truth() {
    let mut rng = Xoshiro256::seed_from_u64(0xDEC0DE);
    let (m, n, s) = (41u64, 37u64, 7u64);
    let nnz = 300usize;
    let mut cells = rng.sample_indices((m * n) as usize, nnz);
    cells.sort_unstable();
    let truth: Vec<(u64, u64, f64)> = cells
        .into_iter()
        .map(|cell| (cell as u64 / n, cell as u64 % n, dyadic(&mut rng)))
        .collect();

    let mut coo = Coo::with_info(LocalInfo::whole(m, n, nnz as u64));
    for &(i, j, v) in &truth {
        coo.push(i, j, v);
    }
    let data = AbhsfData::from_coo(&coo, s, &CostModel::default()).unwrap();
    assert!(data.blocks() > 1, "matrix must span several blocks");

    let fs = MemFs::new();
    let path = std::path::Path::new("kernels-roundtrip/matrix-0.h5spm");
    // Small chunks so the batched fetch crosses container chunk seams.
    store_data_chunked_on(&fs, path, &data, 64).unwrap();
    let reader = H5Reader::open_on(&fs, path).unwrap();
    let dir = BlockDirectory::read(&reader).unwrap();
    assert_eq!(dir.entries.len() as u64, data.blocks());

    let indices: Vec<usize> = (0..dir.entries.len()).collect();
    let mut blocks: Vec<DecodedBlock> = Vec::new();
    // Tiny batch budget: forces a multi-batch prefetch pipeline.
    let decoded = fetch_decoded_blocks_batched(&reader, &dir, &indices, 512, |k, block| {
        let e = &dir.entries[k];
        assert_eq!(block.scheme(), e.scheme, "block {k}: scheme");
        assert_eq!(block.zeta(), e.zeta, "block {k}: zeta");
        assert_eq!(
            block.payload_bytes(),
            expected_payload_bytes(e.scheme, s, e.zeta),
            "block {k}: per-scheme payload bytes"
        );
        blocks.push(block);
    })
    .unwrap();
    assert_eq!(decoded, nnz as u64);

    // Element-exact reconstruction.
    let mut got: Vec<(u64, u64, f64)> = blocks.iter().flat_map(|b| b.elements()).collect();
    got.sort_by_key(|&(i, j, _)| (i, j));
    assert_eq!(got, truth, "decoded elements != stored elements");

    // Kernel product over the decoded blocks == order-independent oracle.
    let x: Vec<f64> = (0..n).map(|_| dyadic(&mut rng)).collect();
    let refs: Vec<&DecodedBlock> = blocks.iter().collect();
    let y = SpmvParts::Blocks { m, n, blocks: &refs }.spmv(&x);
    let mut want = vec![0.0; m as usize];
    for &(i, j, v) in &truth {
        want[i as usize] += v * x[j as usize];
    }
    assert_eq!(y, want, "block-kernel SpMV != truth product");
}

/// A measured table whose per-(s, scheme) affine costs are designed so
/// every scheme wins somewhere: COO → CSR → bitmap → dense as ζ grows
/// (at s = 16), plus a second calibrated size.
fn envelope_table() -> MeasuredCosts {
    let mk = |s, scheme, base_ps, per_elem_ps| MeasuredEntry {
        s,
        scheme,
        base_ps,
        per_elem_ps,
    };
    MeasuredCosts::new(vec![
        mk(16, Scheme::Coo, 100, 1000),
        mk(16, Scheme::Csr, 2000, 800),
        mk(16, Scheme::Bitmap, 20_000, 500),
        mk(16, Scheme::Dense, 100_000, 100),
        mk(64, Scheme::Coo, 400, 1000),
        mk(64, Scheme::Csr, 8000, 800),
        mk(64, Scheme::Bitmap, 80_000, 500),
        mk(64, Scheme::Dense, 1_600_000, 100),
    ])
    .unwrap()
}

/// The hand-estimated s = 8 table used by the decision-flip tests: under
/// it COO/CSR/dense win kernel time where the analytic byte model picks
/// bitmap for nearly every fill.
fn flip_table() -> MeasuredCosts {
    let mk = |scheme, base_ps, per_elem_ps| MeasuredEntry {
        s: 8,
        scheme,
        base_ps,
        per_elem_ps,
    };
    MeasuredCosts::new(vec![
        mk(Scheme::Coo, 500, 900),
        mk(Scheme::Csr, 1220, 700),
        mk(Scheme::Bitmap, 8000, 500),
        mk(Scheme::Dense, 19_200, 150),
    ])
    .unwrap()
}

/// `MeasuredCosts` survives the JSON round trip bit-for-bit, both as the
/// bare table object and embedded under `"table"` the way
/// `BENCH_kernels.json` carries it; malformed tables are rejected.
#[test]
fn measured_table_json_roundtrip() {
    for table in [envelope_table(), flip_table()] {
        let text = table.to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        let back = MeasuredCosts::from_json(&parsed).unwrap();
        assert_eq!(back, table, "bare table round trip");

        // Whole-document form: {"bench": ..., "table": {...}}.
        let doc = format!("{{\"bench\":\"kernels\",\"table\":{text}}}");
        let back = MeasuredCosts::from_json(&Json::parse(&doc).unwrap()).unwrap();
        assert_eq!(back, table, "embedded table round trip");
    }
    // Validation: a block size missing a scheme entry is rejected.
    let incomplete = MeasuredCosts::new(vec![MeasuredEntry {
        s: 8,
        scheme: Scheme::Coo,
        base_ps: 1,
        per_elem_ps: 1,
    }]);
    assert!(incomplete.is_err(), "incomplete table must not validate");
    assert!(MeasuredCosts::new(vec![]).is_err(), "empty table must not validate");
}

/// The committed calibration baseline at the repo root parses, drives a
/// `CostModel`, and labels the manifest the way `store --calibrate`
/// records it.
#[test]
fn committed_bench_table_parses_and_drives_cost_model() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_kernels.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    let doc = Json::parse(&text).unwrap();
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("kernels"));
    let table = MeasuredCosts::from_json(&doc).unwrap();
    assert!(!table.block_sizes().is_empty());
    let model = CostModel::from_measurements(table.clone());
    assert_eq!(model.table_id(), table.label());
    assert!(model.table_id().starts_with("measured(s="));
    // The model answers for uncalibrated sizes too (nearest-s rule).
    for s in [1u64, 8, 13, 100] {
        let chosen = model.choose(s, 1);
        assert!(Scheme::ALL.contains(&chosen));
    }
}

/// `choose` is exactly the argmin of `block_cost` with ties resolved
/// toward the lower scheme tag — for the analytic model and for measured
/// tables alike.
#[test]
fn choose_is_argmin_of_block_cost_for_both_models() {
    let models = [
        ("analytic", CostModel::default()),
        ("envelope", CostModel::from_measurements(envelope_table())),
        ("flip", CostModel::from_measurements(flip_table())),
    ];
    for (label, model) in &models {
        for s in [4u64, 8, 16, 64] {
            for zeta in 0..=s * s {
                let chosen = model.choose(s, zeta);
                let best = model.block_cost(chosen, s, zeta);
                for other in Scheme::ALL {
                    let cost = model.block_cost(other, s, zeta);
                    assert!(
                        best <= cost,
                        "[{label}] s={s} zeta={zeta}: chose {chosen:?} ({best}) \
                         but {other:?} costs {cost}"
                    );
                    if cost == best {
                        assert!(
                            chosen as u8 <= other as u8,
                            "[{label}] s={s} zeta={zeta}: tie must pick lower tag, \
                             got {chosen:?} over {other:?}"
                        );
                    }
                }
            }
        }
    }
}

/// Affine lower envelope ⇒ each scheme wins one contiguous ζ interval:
/// walking ζ from 1 to s², no scheme that stopped winning ever wins
/// again. Verified for measured tables (the analytic model shares the
/// property by the same argument).
#[test]
fn measured_crossovers_are_monotone_in_zeta() {
    for (label, table) in [("envelope", envelope_table()), ("flip", flip_table())] {
        let model = CostModel::from_measurements(table);
        for s in [8u64, 16, 64] {
            let mut seen_done: Vec<Scheme> = Vec::new();
            let mut current: Option<Scheme> = None;
            for zeta in 1..=s * s {
                let w = model.choose(s, zeta);
                if current != Some(w) {
                    if let Some(prev) = current {
                        seen_done.push(prev);
                    }
                    assert!(
                        !seen_done.contains(&w),
                        "[{label}] s={s}: {w:?} wins again at zeta={zeta} after \
                         losing — crossovers not monotone"
                    );
                    current = Some(w);
                }
            }
        }
        // At s=16 the envelope table gives every scheme its own regime.
        if label == "envelope" {
            let winners: Vec<Scheme> =
                [1u64, 30, 100, 250].iter().map(|&z| model.choose(16, z)).collect();
            assert_eq!(
                winners,
                [Scheme::Coo, Scheme::Csr, Scheme::Bitmap, Scheme::Dense],
                "[{label}] expected all four regimes at s=16"
            );
        }
    }
}

/// The acceptance pin: a measured table flips scheme decisions against
/// the analytic byte model, and the flip propagates through
/// `AbhsfData::from_coo` into what actually gets encoded.
#[test]
fn measured_table_flips_scheme_decisions_vs_analytic() {
    let analytic = CostModel::default();
    let measured = CostModel::from_measurements(flip_table());

    // Analytic bytes at s=8, zeta=4: COO 48, CSR 76, bitmap 40, dense 512
    // → bitmap. Measured ps: COO 4100, CSR 4020, bitmap 10000, dense
    // 19800 → CSR. A genuine flip.
    assert_eq!(analytic.choose(8, 4), Scheme::Bitmap);
    assert_eq!(measured.choose(8, 4), Scheme::Csr);
    let flips = (1..=64u64)
        .filter(|&z| analytic.choose(8, z) != measured.choose(8, z))
        .count();
    assert!(flips > 10, "expected many flips at s=8, got {flips}");

    // End to end: the same 4-nonzero block encodes as bitmap under the
    // analytic model and as CSR under the measured one.
    let mut coo = Coo::with_info(LocalInfo::whole(8, 8, 4));
    for (i, j, v) in [(0u64, 1u64, 1.0), (2, 5, -2.0), (4, 4, 0.5), (7, 0, 3.0)] {
        coo.push(i, j, v);
    }
    let a = AbhsfData::from_coo(&coo, 8, &analytic).unwrap();
    let m = AbhsfData::from_coo(&coo, 8, &measured).unwrap();
    assert_eq!(a.schemes, [Scheme::Bitmap as u8]);
    assert_eq!(m.schemes, [Scheme::Csr as u8]);
    // Same matrix either way: both decode paths agree on the product.
    assert_eq!(a.zetas, m.zetas);

    // The calibrated model does not disturb byte accounting: analytic
    // costs are byte-valued regardless of the measured table.
    for scheme in Scheme::ALL {
        assert_eq!(
            measured.analytic_cost(scheme, 8, 4),
            analytic.analytic_cost(scheme, 8, 4),
            "analytic bytes must not change under a measured table"
        );
    }
}
