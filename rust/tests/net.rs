//! Network-layer integration: a `pallas-served` daemon in front of any
//! VFS backend must be behaviorally transparent to the whole coordinator
//! stack — element-identical loads through [`RemoteFs`], typed
//! [`DatasetError`]s (never hangs) when the daemon dies mid-load, bounded
//! retries that absorb transient connection drops, and clean fault
//! propagation when the daemon's *own* backend is a fault-injecting
//! [`SimFs`] (the N-daemon × M-client simulation story of DESIGN.md §11).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use abhsf::coordinator::{Cluster, Dataset, DatasetError, InMemFormat, LoadedMatrix, StoreOptions};
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::mapping::{Colwise, ProcessMapping, Rowwise};
use abhsf::net::{serve, wire, RemoteFs, RetryPolicy, ServeOptions, ServerHandle};
use abhsf::parfs::FsModel;
use abhsf::vfs::{FaultSpec, MemFs, SimFs, Storage};

const P: usize = 3;
const DIR: &str = "/net-test/matrix";

/// Store a small matrix on a fresh MemFs (same workload as the vfs
/// suite); returns the backing map so tests can serve it.
fn mem_dataset() -> MemFs {
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 11), 2));
    let n = gen.dim();
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, P));
    let cluster = Cluster::new(P, 64);
    let mem = MemFs::new();
    let (_, report) = Dataset::store_on(
        Arc::new(mem.clone()),
        &cluster,
        &gen,
        &mapping,
        DIR,
        StoreOptions {
            block_size: 8,
            chunk_elems: 256,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(report.total_nnz() > 0);
    mem
}

/// Serve `backend` on an ephemeral port with the whole namespace exposed
/// (root `/`), so client paths and server paths coincide.
fn serve_root(backend: Arc<dyn Storage>, opts: ServeOptions) -> ServerHandle {
    serve(
        backend,
        "127.0.0.1:0",
        ServeOptions {
            root: "/".into(),
            ..opts
        },
    )
    .unwrap()
}

/// A retry policy tight enough for tests: failures resolve in well under
/// a second instead of the production multi-second budget.
fn fast_policy() -> RetryPolicy {
    RetryPolicy {
        max_retries: 4,
        base_backoff: Duration::from_millis(10),
        max_backoff: Duration::from_millis(100),
        connect_timeout: Duration::from_millis(500),
        io_timeout: Duration::from_secs(5),
    }
}

fn client(h: &ServerHandle) -> RemoteFs {
    RemoteFs::connect_with(&h.addr().to_string(), fast_policy()).unwrap()
}

fn collect(mats: &[LoadedMatrix]) -> Vec<(u64, u64, f64)> {
    let mut out = Vec::new();
    for lm in mats {
        let coo = lm.clone().into_coo();
        let (ro, co) = (coo.info.m_offset, coo.info.n_offset);
        for (i, j, v) in coo.iter() {
            out.push((i + ro, j + co, v));
        }
    }
    out.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    out
}

fn load_coo(dataset: &Dataset, cluster: &Cluster) -> Vec<(u64, u64, f64)> {
    let (mats, _) = dataset
        .load()
        .format(InMemFormat::Coo)
        .run(cluster)
        .unwrap();
    collect(&mats)
}

// ------------------------------------------------------------- contract

/// The full `Storage` surface works over the wire against a mem-backed
/// daemon: files written through the client are readable both ways,
/// positional reads see patched bytes, list/rename behave, and a missing
/// file is a typed `NotFound` — with the daemon's request counter moving.
#[test]
fn remote_storage_contract_over_mem_daemon() {
    let mem = MemFs::new();
    let mut h = serve_root(Arc::new(mem.clone()), ServeOptions::default());
    let fs = client(&h);
    assert_eq!(fs.label(), "remote");
    let dir = Path::new("/contract");
    fs.create_dir_all(dir).unwrap();

    // Whole-file write/read, visible to the daemon's inner backend.
    fs.write_file(&dir.join("a.bin"), b"hello world").unwrap();
    assert_eq!(fs.read_file(&dir.join("a.bin")).unwrap(), b"hello world");
    assert_eq!(fs.len(&dir.join("a.bin")).unwrap(), 11);
    assert_eq!(mem.read_file(&dir.join("a.bin")).unwrap(), b"hello world");

    // Streaming writer: append + back-patch + sync, then positional reads.
    let mut w = fs.create(&dir.join("b.bin")).unwrap();
    w.append(&[0u8; 8]).unwrap();
    w.patch_at(0, &1234u64.to_le_bytes()).unwrap();
    w.append(b"tail").unwrap();
    w.sync().unwrap();
    drop(w);
    let f = fs.open(&dir.join("b.bin")).unwrap();
    assert_eq!(f.len().unwrap(), 12);
    let mut head = [0u8; 8];
    f.read_exact_at(0, &mut head).unwrap();
    assert_eq!(u64::from_le_bytes(head), 1234);
    let mut tail = [0u8; 4];
    f.read_exact_at(8, &mut tail).unwrap();
    assert_eq!(&tail, b"tail");

    // Listing comes back in the client's namespace.
    let mut names = fs.list(dir).unwrap();
    names.sort();
    assert_eq!(names, vec![dir.join("a.bin"), dir.join("b.bin")]);

    // Rename moves the bytes and vacates the source.
    fs.rename(&dir.join("a.bin"), &dir.join("c.bin")).unwrap();
    assert!(fs.read_file(&dir.join("a.bin")).is_err());
    assert_eq!(fs.read_file(&dir.join("c.bin")).unwrap(), b"hello world");

    // Canonical identity is stable under lexical noise (resolved
    // server-side, so every client agrees).
    assert_eq!(
        fs.canonical(&dir.join("sub").join("..").join("c.bin")),
        fs.canonical(&dir.join("c.bin")),
    );

    // Absent file: a typed NotFound, not a hang or an opaque failure.
    let err = fs.open(Path::new("/contract/missing.bin")).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::NotFound, "{err}");

    let stats = fs.stats();
    assert!(stats.requests > 0, "{stats}");
    assert!(h.requests_served() > 0);
    assert_eq!(stats.retries, 0, "healthy daemon should need no retries");
    h.shutdown();
}

// --------------------------------------------------------- differential

/// The acceptance scenario: a dataset stored on the *local filesystem*
/// and served by the daemon loads element-identically through
/// [`RemoteFs`] — same-config fast path and a different-configuration
/// (new mapping, new process count) load both match direct local loads.
#[test]
fn remote_load_matches_local_loads() {
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 11), 2));
    let n = gen.dim();
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, P));
    let cluster = Cluster::new(P, 64);
    let dir = std::env::temp_dir().join(format!("abhsf-net-diff-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (local_ds, _) = Dataset::store(
        &cluster,
        &gen,
        &mapping,
        &dir,
        StoreOptions {
            block_size: 8,
            chunk_elems: 256,
            ..Default::default()
        },
    )
    .unwrap();

    let mut h = serve_root(abhsf::vfs::local(), ServeOptions::default());
    let fs = client(&h);
    let remote_ds = Dataset::open_on(Arc::new(fs.clone()), &dir).unwrap();
    assert_eq!(remote_ds.manifest(), local_ds.manifest());

    // Same configuration (stored mapping, stored process count).
    let same_cluster = Cluster::new(P, 8);
    assert_eq!(
        load_coo(&remote_ds, &same_cluster),
        load_coo(&local_ds, &same_cluster),
        "same-config remote load diverged",
    );

    // Different configuration: colwise mapping on two processes forces
    // the pruned/exchange machinery through the network client.
    let remap: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, 2));
    let two = Cluster::new(2, 8);
    let (remote_mats, _) = remote_ds
        .load()
        .mapping(&remap)
        .format(InMemFormat::Coo)
        .run(&two)
        .unwrap();
    let (local_mats, _) = local_ds
        .load()
        .mapping(&remap)
        .format(InMemFormat::Coo)
        .run(&two)
        .unwrap();
    assert_eq!(
        collect(&remote_mats),
        collect(&local_mats),
        "different-config remote load diverged",
    );

    let stats = fs.stats();
    assert!(stats.requests > 0, "{stats}");
    h.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------- failures

/// Killing the daemon between open and load surfaces as a *typed*
/// [`DatasetError`] within the retry budget — never a hang, never a
/// panic. The load runs on a watchdog thread so a regression toward
/// hanging fails the test instead of wedging the suite.
#[test]
fn daemon_kill_mid_load_is_typed_error_not_hang() {
    let mem = mem_dataset();
    let mut h = serve_root(Arc::new(mem.clone()), ServeOptions::default());
    let policy = RetryPolicy {
        max_retries: 2,
        base_backoff: Duration::from_millis(10),
        max_backoff: Duration::from_millis(50),
        connect_timeout: Duration::from_millis(250),
        io_timeout: Duration::from_secs(1),
    };
    let fs = RemoteFs::connect_with(&h.addr().to_string(), policy).unwrap();
    let dataset = Dataset::open_on(Arc::new(fs), DIR).unwrap();

    // Daemon dies; every pooled connection is now dead and redials are
    // refused.
    h.shutdown();

    let (tx, rx) = mpsc::channel();
    thread::spawn(move || {
        let cluster = Cluster::new(P, 8);
        let verdict = match dataset.load().format(InMemFormat::Coo).run(&cluster) {
            Ok(_) => None,
            Err(e) => Some((
                matches!(
                    e,
                    DatasetError::Internal(_) | DatasetError::MissingFile { .. }
                ),
                e.to_string(),
            )),
        };
        let _ = tx.send(verdict);
    });
    match rx.recv_timeout(Duration::from_secs(30)) {
        Ok(Some((typed, msg))) => assert!(typed, "untyped error after daemon kill: {msg}"),
        Ok(None) => panic!("load succeeded against a dead daemon"),
        Err(_) => panic!("load hung after daemon kill instead of erroring"),
    }
}

/// Transient connection drops (the daemon hangs up before every Nth
/// request) are absorbed by bounded retry-with-backoff: the load still
/// succeeds element-identically and the client counted its retries and
/// reconnects.
#[test]
fn transient_drops_are_retried_to_success() {
    let mem = mem_dataset();
    let direct = Dataset::open_on(Arc::new(mem.clone()), DIR).unwrap();
    let cluster = Cluster::new(P, 8);
    let want = load_coo(&direct, &cluster);

    let mut h = serve_root(
        Arc::new(mem.clone()),
        ServeOptions {
            drop_every: 4,
            ..Default::default()
        },
    );
    let fs = client(&h);
    let dataset = Dataset::open_on(Arc::new(fs.clone()), DIR).unwrap();
    assert_eq!(load_coo(&dataset, &cluster), want, "retried load diverged");

    let stats = fs.stats();
    assert!(stats.retries >= 1, "no retries counted: {stats}");
    assert!(stats.reconnects >= 1, "no reconnects counted: {stats}");
    h.shutdown();
}

/// A fault injected *behind* the daemon (SimFs missing-file on the
/// daemon's own backend) crosses the wire as the same typed error a
/// local load would see: `DatasetError::MissingFile` naming the absent
/// container — the single-daemon cell of the N-daemon × M-client
/// simulation story.
#[test]
fn sim_fault_behind_daemon_propagates_typed() {
    let mem = mem_dataset();
    let sim = Arc::new(
        SimFs::new(Arc::new(mem.clone()), FsModel::local_nvme())
            .faults(FaultSpec::parse("missing:matrix-1").unwrap()),
    );
    let mut h = serve_root(sim, ServeOptions::default());
    let fs = client(&h);
    let dataset = Dataset::open_on(Arc::new(fs), DIR).unwrap();
    let cluster = Cluster::new(P, 8);
    let err = dataset
        .load()
        .format(InMemFormat::Coo)
        .run(&cluster)
        .expect_err("missing container behind the daemon must fail the load");
    match err {
        DatasetError::MissingFile { path, source } => {
            assert!(path.ends_with("matrix-1.h5spm"), "{}", path.display());
            assert_eq!(source.kind(), std::io::ErrorKind::NotFound, "{source}");
        }
        other => panic!("expected MissingFile, got {other}"),
    }
    h.shutdown();
}

// ----------------------------------------------------------- concurrency

/// Several clients hammer one daemon concurrently and every one of them
/// decodes the identical element set.
#[test]
fn concurrent_clients_agree() {
    let mem = mem_dataset();
    let direct = Dataset::open_on(Arc::new(mem.clone()), DIR).unwrap();
    let want = load_coo(&direct, &Cluster::new(P, 8));

    let mut h = serve_root(Arc::new(mem.clone()), ServeOptions::default());
    let addr = h.addr().to_string();
    let workers: Vec<_> = (0..4)
        .map(|_| {
            let addr = addr.clone();
            thread::spawn(move || {
                let fs = RemoteFs::connect_with(&addr, fast_policy()).unwrap();
                let dataset = Dataset::open_on(Arc::new(fs), DIR).unwrap();
                load_coo(&dataset, &Cluster::new(P, 8))
            })
        })
        .collect();
    for (i, w) in workers.into_iter().enumerate() {
        assert_eq!(w.join().unwrap(), want, "client {i} diverged");
    }
    h.shutdown();
}

// ----------------------------------------------------------------- stats

/// On a healthy daemon the server-observed counters equal the client's
/// [`abhsf::net::NetStats`] view *exactly*: every request frame the
/// client counted was fully read and counted by the server, `bytes_in`
/// mirrors `wire_sent_bytes`, `bytes_out` mirrors `wire_received_bytes`
/// (handshakes excluded on both sides). The in-process
/// [`ServerHandle::stats`] accessor makes the comparison exact — the
/// wire `Stats` probe itself is then counted as one more request.
#[test]
fn server_counters_match_client_netstats_on_healthy_daemon() {
    let mem = mem_dataset();
    let mut h = serve_root(Arc::new(mem.clone()), ServeOptions::default());
    let fs = client(&h);
    let dataset = Dataset::open_on(Arc::new(fs.clone()), DIR).unwrap();
    let _ = load_coo(&dataset, &Cluster::new(P, 8));

    let cs = fs.stats();
    assert_eq!(cs.retries, 0, "healthy daemon needed retries: {cs}");
    let ss = h.stats();
    assert_eq!(ss.requests, cs.requests, "server {ss} vs client {cs}");
    assert_eq!(ss.bytes_in, cs.wire_sent_bytes, "server {ss} vs client {cs}");
    assert_eq!(ss.bytes_out, cs.wire_received_bytes, "server {ss} vs client {cs}");
    assert_eq!(ss.errors, 0, "{ss}");
    assert!(ss.connections >= 1, "{ss}");

    // Over the wire: the probe's own request frame is read — and counted
    // — before the reply snapshot is taken, so `requests` grows by
    // exactly the probe.
    let ws = fs.server_stats().unwrap();
    assert_eq!(ws.requests, ss.requests + 1, "wire {ws} vs snapshot {ss}");
    assert!(ws.bytes_in > ss.bytes_in, "wire {ws} vs snapshot {ss}");
    assert!(ws.uptime_ms >= ss.uptime_ms, "wire {ws} vs snapshot {ss}");

    // Ping round-trips and measures a finite RTT.
    let rtt = fs.ping().unwrap();
    assert!(rtt.as_secs_f64() >= 0.0);
    h.shutdown();
}

/// Under transient connection drops the client may count attempts the
/// server never saw (a frame written into a connection the daemon had
/// already hung up on), but never the other way around — the divergence
/// is bounded by the retry count, and dropped frames the server *did*
/// read before hanging up are counted on both sides.
#[test]
fn server_counters_bounded_by_retries_under_drops() {
    let mem = mem_dataset();
    let mut h = serve_root(
        Arc::new(mem.clone()),
        ServeOptions {
            drop_every: 4,
            ..Default::default()
        },
    );
    let fs = client(&h);
    let dataset = Dataset::open_on(Arc::new(fs.clone()), DIR).unwrap();
    let _ = load_coo(&dataset, &Cluster::new(P, 8));

    let cs = fs.stats();
    let ss = h.stats();
    assert!(cs.retries >= 1, "drop_every=4 produced no retries: {cs}");
    assert!(
        ss.requests <= cs.requests,
        "server saw frames the client never sent: server {ss} vs client {cs}"
    );
    assert!(
        cs.requests - ss.requests <= cs.retries,
        "divergence beyond the retry budget: server {ss} vs client {cs}"
    );
    assert!(
        ss.bytes_in <= cs.wire_sent_bytes,
        "server read more than the client wrote: server {ss} vs client {cs}"
    );
    // Hang-ups are transport faults, not request errors.
    assert_eq!(ss.errors, 0, "{ss}");
    // Every client reconnect is a fresh accepted connection.
    assert!(
        ss.connections >= 1 + cs.reconnects,
        "server {ss} vs client {cs}"
    );
    h.shutdown();
}

// -------------------------------------------------------------- protocol

/// A client speaking the wrong protocol version gets the server's
/// version in the welcome (so it can report both numbers) and a clean
/// close — no bytes interpreted under the wrong framing.
#[test]
fn version_mismatch_is_welcome_then_close() {
    let mut h = serve_root(Arc::new(MemFs::new()), ServeOptions::default());
    let mut sock = TcpStream::connect(h.addr()).unwrap();
    sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    // Hand-rolled hello claiming a future version 99.
    let mut hello = [0u8; 8];
    hello[..4].copy_from_slice(&wire::HELLO_MAGIC);
    hello[4..6].copy_from_slice(&99u16.to_le_bytes());
    sock.write_all(&hello).unwrap();

    let (version, _medium) = wire::read_welcome(&mut sock).unwrap();
    assert_eq!(version, wire::VERSION, "welcome must carry the server version");
    let mut probe = [0u8; 1];
    assert_eq!(
        sock.read(&mut probe).unwrap(),
        0,
        "server must hang up after a version mismatch"
    );
    h.shutdown();
}
