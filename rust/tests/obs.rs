//! End-to-end exercise of the process-wide tracer (`abhsf::obs::trace`).
//!
//! This is deliberately the only test in this binary: the tracer is
//! process-global, and any concurrently running test that touches an
//! instrumented subsystem (cache claims, serve loops) would emit into
//! the enabled sink — a span of theirs still open at `finish()` would
//! fail the well-formedness check. One test per process keeps the file
//! deterministic.

use abhsf::obs::trace::{
    adopt_parent, check, current_id, enable, finish, is_enabled, point, read_trace, span,
    summarize, Tag,
};

/// Enable into a temp file, emit nested spans (including a cross-thread
/// adopted parent), finish, then parse + check + summarize the file.
#[test]
fn global_tracer_end_to_end() {
    let path = std::env::temp_dir().join(format!("abhsf-obs-trace-{}.jsonl", std::process::id()));
    assert!(!is_enabled());
    let g = span("query", &[("kq", Tag::S("noop"))]);
    drop(g); // inert: must not emit once enabled later
    enable(&path).unwrap();
    assert!(is_enabled());
    {
        let _q = span("query", &[("kq", Tag::S("rect")), ("n", Tag::U(7))]);
        point("cache_claim", &[("outcome", Tag::S("miss"))]);
        let parent = current_id();
        assert_ne!(parent, 0);
        let handle = std::thread::spawn(move || {
            adopt_parent(parent);
            let _b = span("prefetch_batch", &[("ranges", Tag::U(3))]);
            let _v = span("vfs_read", &[("bytes", Tag::U(4096))]);
        });
        handle.join().unwrap();
    }
    finish().unwrap();
    assert!(!is_enabled());
    let events = read_trace(&path).unwrap();
    check(&events).unwrap();
    let s = summarize(&events);
    assert_eq!(s.spans, 3);
    assert_eq!(s.points, 1);
    let chain = s.chain.join("\n");
    assert!(chain.contains("prefetch_batch"), "{chain}");
    assert!(chain.contains("    vfs_read"), "{chain}");
    let _ = std::fs::remove_file(&path);
}
