//! Property-style integration tests: store → load equivalence across the
//! full configuration matrix (seed kinds × mappings × block sizes ×
//! process counts × strategies × in-memory formats).
//!
//! No `proptest` in the offline registry, so cases are driven by the
//! crate's deterministic RNG over a seeded parameter grid — every failure
//! reproduces from the printed case description.

use std::collections::HashMap;
use std::sync::Arc;

use abhsf::coordinator::{Cluster, Dataset, InMemFormat, StoreOptions, Strategy};
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::mapping::{Block2d, Colwise, CyclicRows, ProcessMapping, Rowwise};

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("abhsf-roundtrip-configs")
        .join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Global element map of a generator (the oracle).
fn oracle(gen: &KroneckerGen) -> HashMap<(u64, u64), f64> {
    let mut m = HashMap::new();
    gen.visit_row_range(0, gen.dim(), |i, j, v| {
        m.insert((i, j), v);
    });
    m
}

/// Collect the global elements of loaded parts.
fn collect(mats: &[abhsf::coordinator::LoadedMatrix]) -> HashMap<(u64, u64), f64> {
    let mut m = HashMap::new();
    for lm in mats {
        let coo = lm.clone().into_coo();
        let (ro, co) = (coo.info.m_offset, coo.info.n_offset);
        for (r, c, v) in coo.iter() {
            assert!(
                m.insert((r + ro, c + co), v).is_none(),
                "duplicate global element ({}, {})",
                r + ro,
                c + co
            );
        }
    }
    m
}

#[test]
fn same_config_roundtrip_grid() {
    // Sweep seeds × block sizes × P; both in-memory formats.
    let cases = [
        ("cage", 8u64, 2u32, 4u64, 3usize),
        ("cage", 10, 2, 16, 5),
        ("rmat", 16, 2, 8, 4),
        ("random", 12, 2, 32, 2),
        ("diag", 9, 2, 8, 3),
    ];
    for (kind, seed_n, order, block, p) in cases {
        let seed = match kind {
            "cage" => SeedMatrix::cage_like(seed_n, 1),
            "rmat" => SeedMatrix::rmat((seed_n as f64).log2().ceil() as u32, 4, 2),
            "random" => SeedMatrix::random(seed_n, 0.15, 3),
            _ => SeedMatrix::diagonal(seed_n),
        };
        let gen = Arc::new(KroneckerGen::new(seed, order));
        let n = gen.dim();
        let mapping: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(p));
        let cluster = Cluster::new(p, 64);
        let dir = tmpdir(&format!("same-{kind}-{seed_n}-{block}-{p}"));
        let (dataset, _) = Dataset::store(
            &cluster,
            &gen,
            &mapping,
            &dir,
            StoreOptions {
                block_size: block,
                ..Default::default()
            },
        )
        .unwrap();
        for format in [InMemFormat::Csr, InMemFormat::Coo] {
            let (mats, report) = dataset.load().format(format).run(&cluster).unwrap();
            assert_eq!(report.scenario, "same-config", "auto must take the fast path");
            assert_eq!(
                report.total_nnz(),
                gen.nnz(),
                "case {kind}/{seed_n}/{block}/{p}"
            );
            for m in &mats {
                m.validate().unwrap();
            }
            assert_eq!(collect(&mats), oracle(&gen), "case {kind} n={n}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn diff_config_roundtrip_grid() {
    // Store row-wise with p_store, reload under every mapping family and
    // strategy with several p_load values.
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(9, 4), 2));
    let n = gen.dim();
    let p_store = 4;
    let store_map: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(p_store));
    let store_cluster = Cluster::new(p_store, 64);
    let dir = tmpdir("diff-grid");
    let (dataset, _) = Dataset::store(
        &store_cluster,
        &gen,
        &store_map,
        &dir,
        StoreOptions {
            block_size: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let want = oracle(&gen);

    let mappings: Vec<(String, Arc<dyn ProcessMapping>)> = vec![
        ("colwise-3".into(), Arc::new(Colwise::regular(n, n, 3))),
        ("rowwise-5".into(), Arc::new(Rowwise::regular(n, n, 5))),
        ("2d-2x3".into(), Arc::new(Block2d::regular(n, n, 2, 3))),
        ("cyclic-4".into(), Arc::new(CyclicRows { m: n, n, p: 4 })),
    ];
    for (label, mapping) in mappings {
        let p_load = mapping.nprocs();
        let cluster = Cluster::new(p_load, 64);
        for strategy in [Strategy::Independent, Strategy::Collective] {
            let (mats, report) = dataset
                .load()
                .mapping(&mapping)
                .strategy(strategy)
                .format(InMemFormat::Csr)
                .run(&cluster)
                .unwrap();
            assert_eq!(report.total_nnz(), gen.nnz(), "{label}/{strategy:?}");
            assert_eq!(collect(&mats), want, "{label}/{strategy:?}");
        }
        // Exchange loader must agree too.
        let (mats, report) = dataset
            .load()
            .mapping(&mapping)
            .strategy(Strategy::Exchange)
            .format(InMemFormat::Coo)
            .run(&cluster)
            .unwrap();
        assert_eq!(report.total_nnz(), gen.nnz(), "{label}/exchange");
        assert_eq!(collect(&mats), want, "{label}/exchange");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ownership_respects_mapping() {
    // Every loaded element must belong to its rank under M(i, j).
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 6), 2));
    let n = gen.dim();
    let p_store = 3;
    let store_map: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(p_store));
    let store_cluster = Cluster::new(p_store, 64);
    let dir = tmpdir("ownership");
    let (dataset, _) = Dataset::store(
        &store_cluster,
        &gen,
        &store_map,
        &dir,
        StoreOptions::default(),
    )
    .unwrap();
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Block2d::regular(n, n, 2, 2));
    let cluster = Cluster::new(4, 64);
    let (mats, _) = dataset
        .load()
        .mapping(&mapping)
        .strategy(Strategy::Independent)
        .format(InMemFormat::Coo)
        .run(&cluster)
        .unwrap();
    for (rank, lm) in mats.iter().enumerate() {
        let coo = lm.clone().into_coo();
        for (r, c, _) in coo.iter() {
            let (i, j) = (r + coo.info.m_offset, c + coo.info.n_offset);
            assert_eq!(mapping.owner(i, j), rank, "element ({i},{j}) on rank {rank}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn block_size_sweep_preserves_content() {
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(10, 8), 2));
    let want = oracle(&gen);
    let p = 2;
    let mapping: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(p));
    let cluster = Cluster::new(p, 64);
    for block in [2u64, 3, 7, 16, 64, 128] {
        let dir = tmpdir(&format!("bs-{block}"));
        let (dataset, _) = Dataset::store(
            &cluster,
            &gen,
            &mapping,
            &dir,
            StoreOptions {
                block_size: block,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(dataset.block_size(), block);
        let (mats, _) = dataset
            .load()
            .format(InMemFormat::Csr)
            .run(&cluster)
            .unwrap();
        assert_eq!(collect(&mats), want, "block size {block}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn chunk_size_sweep_preserves_content() {
    // Container chunking must be invisible to the loader.
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 2), 2));
    let want = oracle(&gen);
    let cluster = Cluster::new(2, 64);
    let mapping: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(2));
    for chunk in [1u64, 7, 64, 100_000] {
        let dir = tmpdir(&format!("chunk-{chunk}"));
        let (dataset, _) = Dataset::store(
            &cluster,
            &gen,
            &mapping,
            &dir,
            StoreOptions {
                block_size: 8,
                chunk_elems: chunk,
                ..Default::default()
            },
        )
        .unwrap();
        let (mats, report) = dataset
            .load()
            .format(InMemFormat::Csr)
            .run(&cluster)
            .unwrap();
        assert_eq!(collect(&mats), want, "chunk {chunk}");
        // Smaller chunks => more read ops.
        if chunk == 1 {
            assert!(report.per_rank_io[0].ops > 100, "tiny chunks should mean many ops");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
