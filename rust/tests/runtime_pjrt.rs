//! End-to-end PJRT integration: the AOT artifacts produced by
//! `python/compile/aot.py` must compile on the PJRT CPU client and agree
//! numerically with the native Rust implementations.
//!
//! Skips (with a message) if `make artifacts` has not run.

use abhsf::formats::{Coo, Csr, LocalInfo};
use abhsf::runtime::{BlockedTensors, Manifest, Runtime};
use abhsf::util::rng::Xoshiro256;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime_or_skip() -> Option<Runtime> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    match Runtime::new(Manifest::load(dir).expect("manifest parses")) {
        Ok(rt) => Some(rt),
        Err(e) => {
            // Environment-dependent: the PJRT client needs the `pjrt`
            // cargo feature plus a native xla_extension install.
            eprintln!("SKIP: pjrt runtime unavailable ({e})");
            None
        }
    }
}

fn random_csr(seed: u64, m: u64, n: u64, per_row: usize) -> Csr {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let info = LocalInfo::whole(m, n, (m as usize * per_row) as u64);
    let mut coo = Coo::with_info(info);
    let mut seen = std::collections::HashSet::new();
    // One 16-wide column cluster per 8-row group keeps the distinct blocks
    // per block row within every artifact's K (cluster spans <= 3 blocks
    // at s=8, <= 2 at s=16).
    let groups = m.div_ceil(8);
    let bases: Vec<u64> = (0..groups)
        .map(|_| rng.next_below(n.saturating_sub(16).max(1)))
        .collect();
    for r in 0..m {
        let base = bases[(r / 8) as usize];
        for _ in 0..per_row {
            let c = (base + rng.next_below(16)).min(n - 1);
            if seen.insert((r, c)) {
                coo.push(r, c, rng.range_f64(-1.0, 1.0));
            }
        }
    }
    Csr::from_coo(&coo)
}

#[test]
fn spmv_artifact_matches_native_rust() {
    let Some(rt) = runtime_or_skip() else { return };
    println!("platform = {}", rt.platform());
    let csr = random_csr(11, 128, 128, 6);
    let x: Vec<f64> = (0..128).map(|i| ((i % 13) as f64) * 0.3 - 1.5).collect();

    let y_pjrt = rt.spmv_csr(&csr, &x).expect("pjrt spmv");
    let mut y_native = vec![0.0f64; 128];
    csr.spmv_into(&x, &mut y_native);

    assert!(y_pjrt.len() >= 128);
    for i in 0..128 {
        let diff = (y_pjrt[i] as f64 - y_native[i]).abs();
        assert!(diff < 1e-3, "row {i}: pjrt {} vs native {}", y_pjrt[i], y_native[i]);
    }
    // Rows beyond m_local are padding and must be exactly zero.
    for (i, &v) in y_pjrt.iter().enumerate().skip(128) {
        assert_eq!(v, 0.0, "padding row {i}");
    }
}

#[test]
fn spmv_artifact_respects_offsets() {
    let Some(rt) = runtime_or_skip() else { return };
    // A column-window submatrix (like a diff-config colwise part).
    let mut rng = Xoshiro256::seed_from_u64(3);
    let info = LocalInfo {
        m: 256,
        n: 512,
        z: 600,
        m_local: 256,
        n_local: 128,
        z_local: 0,
        m_offset: 0,
        n_offset: 256,
    };
    let mut coo = Coo::with_info(info);
    let mut seen = std::collections::HashSet::new();
    while coo.nnz() < 600 {
        let r = rng.next_below(256);
        let c = rng.next_below(128);
        if seen.insert((r, c)) {
            coo.push(r, c, rng.range_f64(-1.0, 1.0));
        }
    }
    let csr = Csr::from_coo(&coo);
    let x: Vec<f64> = (0..512).map(|i| (i as f64 * 0.01).sin()).collect();
    let y_pjrt = rt.spmv_csr(&csr, &x).expect("pjrt spmv");
    let mut y_native = vec![0.0f64; 256];
    csr.spmv_into(&x, &mut y_native);
    for i in 0..256 {
        assert!(
            (y_pjrt[i] as f64 - y_native[i]).abs() < 1e-3,
            "row {i}: {} vs {}",
            y_pjrt[i],
            y_native[i]
        );
    }
}

#[test]
fn power_step_artifact_normalizes() {
    let Some(rt) = runtime_or_skip() else { return };
    let art = rt
        .manifest()
        .of_kind("power_step")
        .first()
        .cloned()
        .cloned()
        .expect("a power_step artifact");
    let n = art.param("n").unwrap() as usize;
    let csr = random_csr(5, n as u64, n as u64, 5);
    let t = BlockedTensors::pack_csr(&csr, &art).expect("pack");
    let x = vec![1.0f32; n];
    let (x2, norm) = rt.power_step(&art, &t, &x).expect("power step");
    assert!(norm > 0.0);
    let l2: f32 = x2.iter().map(|v| v * v).sum::<f32>().sqrt();
    assert!((l2 - 1.0).abs() < 1e-4, "norm of x' = {l2}");
    // Iterating a few steps must keep producing unit vectors.
    let (x3, _) = rt.power_step(&art, &t, &x2).expect("second step");
    let l3: f32 = x3.iter().map(|v| v * v).sum::<f32>().sqrt();
    assert!((l3 - 1.0).abs() < 1e-4);
}

#[test]
fn assemble_artifact_matches_native_decode() {
    let Some(rt) = runtime_or_skip() else { return };
    let art = rt
        .manifest()
        .of_kind("assemble")
        .first()
        .cloned()
        .cloned()
        .expect("an assemble artifact");
    let z = art.param("z").unwrap() as usize;
    let t = art.param("t").unwrap() as usize;
    let s = art.param("s").unwrap() as usize;
    let mut rng = Xoshiro256::seed_from_u64(21);
    let mut lrows = vec![0i32; z * t];
    let mut lcols = vec![0i32; z * t];
    let mut vals = vec![0f32; z * t];
    for b in 0..z {
        let fill = rng.range_usize(0, t);
        for slot in 0..fill {
            lrows[b * t + slot] = rng.next_below(s as u64) as i32;
            lcols[b * t + slot] = rng.next_below(s as u64) as i32;
            vals[b * t + slot] = rng.range_f64(-1.0, 1.0) as f32;
        }
    }
    let out = rt.assemble(&art, &lrows, &lcols, &vals).expect("assemble");
    assert_eq!(out.len(), z * s * s);
    // Native scatter oracle.
    let mut want = vec![0f32; z * s * s];
    for b in 0..z {
        for slot in 0..t {
            let v = vals[b * t + slot];
            if v != 0.0 {
                let (r, c) = (lrows[b * t + slot] as usize, lcols[b * t + slot] as usize);
                want[b * s * s + r * s + c] += v;
            }
        }
    }
    for (i, (&g, &w)) in out.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-5, "elem {i}: {g} vs {w}");
    }
}

#[test]
fn executables_are_cached() {
    let Some(rt) = runtime_or_skip() else { return };
    let name = &rt.manifest().artifacts[0].name.clone();
    let a = rt.executable(name).expect("first compile");
    let b = rt.executable(name).expect("cached");
    assert!(std::sync::Arc::ptr_eq(&a, &b), "second call must hit the cache");
}
