//! Serving-layer integration: the cached random-access reader
//! (`Dataset::reader`) against full `LoadPlan` loads, under concurrency
//! and under byte-budget pressure.

use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

use abhsf::abhsf::{matrix_file_path, BlockDirectory, Scheme};
use abhsf::cache::{BlockCache, BLOCK_FIXED_BYTES};
use abhsf::coordinator::{Cluster, Dataset, InMemFormat, StoreOptions};
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::h5::H5Reader;
use abhsf::mapping::{ProcessMapping, Rowwise};
use abhsf::util::rng::Xoshiro256;
use abhsf::vfs::{MemFs, Storage};

type Elem = (u64, u64, f64);

/// Store a Kronecker dataset on `storage` and return the handle, the
/// reference elements from a full `LoadPlan` load (global coordinates,
/// sorted lexicographically) and the global dimension.
fn setup(
    storage: Arc<dyn Storage>,
    name: &str,
    p: usize,
    s: u64,
) -> (Dataset, Vec<Elem>, u64) {
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 7), 2));
    let n = gen.dim();
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p));
    let cluster = Cluster::new(p, 64);
    let dir = std::env::temp_dir().join(format!(
        "abhsf-serve-test-{name}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let (dataset, _) = Dataset::store_on(
        storage,
        &cluster,
        &gen,
        &mapping,
        &dir,
        StoreOptions {
            block_size: s,
            ..Default::default()
        },
    )
    .unwrap();
    let (mats, report) = dataset
        .load()
        .format(InMemFormat::Coo)
        .run(&cluster)
        .unwrap();
    assert_eq!(report.total_nnz(), gen.nnz());
    let mut reference: Vec<Elem> = Vec::new();
    for m in mats {
        let coo = m.into_coo();
        let (ro, co) = (coo.info.m_offset, coo.info.n_offset);
        for (i, j, v) in coo.iter() {
            reference.push((i + ro, j + co, v));
        }
    }
    reference.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    (dataset, reference, n)
}

/// Random half-open span inside `[0, extent)`, at least one wide.
fn span(rng: &mut Xoshiro256, extent: u64) -> (u64, u64) {
    let len = 1 + rng.next_below(extent);
    let start = rng.next_below(extent - len + 1);
    (start, start + len)
}

/// The reference elements inside `rows × cols`.
fn rect_filter(reference: &[Elem], rows: (u64, u64), cols: (u64, u64)) -> Vec<Elem> {
    reference
        .iter()
        .copied()
        .filter(|&(i, j, _)| i >= rows.0 && i < rows.1 && j >= cols.0 && j < cols.1)
        .collect()
}

/// Scheme-native payload bytes of one stored block under the default
/// byte widths — the independent formula the cache's per-block charge
/// (`DecodedBlock::payload_bytes`) must reproduce.
fn scheme_payload_bytes(scheme: Scheme, s: u64, zeta: u64) -> u64 {
    match scheme {
        Scheme::Coo => (2 + 2 + 8) * zeta,
        Scheme::Csr => 4 * (s + 1) + (2 + 8) * zeta,
        Scheme::Bitmap => (s * s).div_ceil(8) + 8 * zeta,
        Scheme::Dense => 8 * s * s,
    }
}

/// Walk every stored block directory of `dataset` and return
/// `(block_count, per_scheme_bytes, triplet_bytes)`: the cache charge
/// all blocks should account to under scheme-native storage, and what
/// the same working set would cost expanded to 24-byte triplets.
fn accounting_for(storage: &Arc<dyn Storage>, dataset: &Dataset) -> (u64, u64, u64) {
    let (mut blocks, mut native, mut triplets) = (0u64, 0u64, 0u64);
    for rank in 0..dataset.nprocs() {
        let path = matrix_file_path(dataset.dir(), rank);
        let reader = H5Reader::open_on(storage.as_ref(), &path).unwrap();
        let dir = BlockDirectory::read(&reader).unwrap();
        let s = dir.header.block_size;
        for e in &dir.entries {
            blocks += 1;
            native += BLOCK_FIXED_BYTES + scheme_payload_bytes(e.scheme, s, e.zeta);
            triplets += BLOCK_FIXED_BYTES + 24 * e.zeta;
        }
    }
    (blocks, native, triplets)
}

/// Differential: every random rect / row-slice / nnz / SpMV answer of a
/// cached reader is element-identical to the full `LoadPlan` load, on
/// both the local filesystem and the in-memory backend — and once warm,
/// repeated queries never touch storage.
#[test]
fn cached_queries_match_full_load_on_local_and_mem() {
    for (label, storage) in [
        ("local", abhsf::vfs::local()),
        ("mem", Arc::new(MemFs::new()) as Arc<dyn Storage>),
    ] {
        let (dataset, reference, n) = setup(Arc::clone(&storage), &format!("diff-{label}"), 3, 8);
        let cache = BlockCache::with_budget(64 << 20);
        let reader = dataset.reader(&cache).unwrap();
        assert_eq!(reader.dims(), (n, n));
        let mut rng = Xoshiro256::seed_from_u64(0xD1FF ^ n);
        let mut union: HashSet<(u64, u64)> = HashSet::new();
        for q in 0..24 {
            let (r0, r1) = span(&mut rng, n);
            let (c0, c1) = span(&mut rng, n);
            let got = reader.rect(r0..r1, c0..c1).unwrap();
            let want = rect_filter(&reference, (r0, r1), (c0, c1));
            assert_eq!(got, want, "[{label}] query {q}: rect {r0}..{r1} x {c0}..{c1}");
            assert_eq!(
                reader.nnz_in(r0..r1, c0..c1).unwrap(),
                want.len() as u64,
                "[{label}] nnz_in disagrees with rect"
            );
            union.extend(got.iter().map(|&(i, j, _)| (i, j)));
        }
        assert!(union.len() <= reference.len());
        // The whole-matrix rect IS the full load.
        let all = reader.rect(0..n, 0..n).unwrap();
        assert_eq!(all, reference, "[{label}] full rect != full load");
        // row_slice is rect over every column.
        let rows = reader.row_slice(1..n / 2).unwrap();
        assert_eq!(rows, rect_filter(&reference, (1, n / 2), (0, n)));
        // SpMV through the cache equals the reference product (1e-9:
        // block order regroups the per-row FP summation).
        let x: Vec<f64> = (0..n).map(|i| ((i % 17) as f64) * 0.25 + 1.0).collect();
        let y = reader.spmv(&x).unwrap();
        let mut want = vec![0.0; n as usize];
        for &(i, j, v) in &reference {
            want[i as usize] += v * x[j as usize];
        }
        assert!(
            abhsf::spmv::max_abs_diff(&y, &want) < 1e-9,
            "[{label}] spmv diverged"
        );
        // Kernel dimension: the block-kernel SpMV is deterministic —
        // the same query through two fresh caches yields a bit-identical
        // product (same block order, same per-element summation) and
        // identical miss counts (misses are a pure function of the query
        // stream, not of scheduling).
        let ca = BlockCache::with_budget(64 << 20);
        let cb = BlockCache::with_budget(64 << 20);
        let ya = dataset.reader(&ca).unwrap().spmv(&x).unwrap();
        let yb = dataset.reader(&cb).unwrap().spmv(&x).unwrap();
        assert_eq!(ya, yb, "[{label}] spmv not deterministic across fresh caches");
        assert_eq!(ya, y, "[{label}] fresh-cache spmv != warm-cache spmv");
        let (sa, sb) = (ca.stats(), cb.stats());
        assert_eq!(sa.misses, sb.misses, "[{label}] miss counts diverged");
        assert_eq!(sa.hits, sb.hits, "[{label}] hit counts diverged");
        assert!(sa.misses > 0, "[{label}] whole-matrix spmv must decode blocks");
        // Everything is resident now (the budget dwarfs the dataset):
        // warm queries must not touch storage at all.
        let st = cache.stats();
        assert_eq!(st.evictions, 0, "budget was ample: {st:?}");
        // Per-scheme byte accounting: every block is resident, and the
        // cache charges each one its scheme-native payload plus the
        // fixed overhead — strictly less than the same working set
        // expanded to 24-byte triplets (no triplet expansion anywhere).
        let (blocks, native, triplets) = accounting_for(&storage, &dataset);
        assert_eq!(st.resident_blocks, blocks, "[{label}] not all blocks resident");
        assert_eq!(st.resident_bytes, native, "[{label}] resident bytes != per-scheme accounting");
        assert!(
            native < triplets,
            "[{label}] scheme-native accounting ({native}) not below triplet \
             expansion ({triplets})"
        );
        let io_before = reader.io_stats();
        let again = reader.rect(0..n, 0..n).unwrap();
        assert_eq!(again, reference);
        assert_eq!(reader.nnz_in(0..n, 0..n).unwrap(), reference.len() as u64);
        let io_after = reader.io_stats();
        assert_eq!(
            (io_before.bytes, io_before.ops),
            (io_after.bytes, io_after.ops),
            "[{label}] warm queries touched storage"
        );
        let _ = std::fs::remove_dir_all(dataset.dir());
    }
}

/// Stress: 8 threads issue overlapping random queries under a budget a
/// quarter of the working set. Completion within the watchdog proves no
/// deadlock; every answer stays correct, evictions occur, residency
/// respects the budget, and a repeated full query after eviction still
/// answers correctly.
#[test]
fn stress_under_small_budget() {
    let (tx, rx) = std::sync::mpsc::channel();
    let worker = std::thread::spawn(move || {
        let storage: Arc<dyn Storage> = Arc::new(MemFs::new());
        let (dataset, reference, n) = setup(storage, "stress", 4, 8);
        // Working set = decoded bytes of every block, measured exactly by
        // one warm pass through an unbounded cache.
        let probe = BlockCache::with_budget(u64::MAX);
        let probe_reader = dataset.reader(&probe).unwrap();
        let all = probe_reader.rect(0..n, 0..n).unwrap();
        assert_eq!(all, reference);
        let ws = probe.stats().resident_bytes;
        assert!(ws > 0);

        let budget = ws / 4;
        // One shard: the quarter-size budget is enforced globally (a
        // 16-way split could leave each slice smaller than one block,
        // which would make residency — and therefore hits — impossible
        // by construction rather than by pressure).
        let cache = BlockCache::with_budget_sharded(budget, 1);
        let threads = 8;
        std::thread::scope(|scope| {
            for t in 0..threads {
                let cache = &cache;
                let dataset = &dataset;
                let reference = &reference;
                scope.spawn(move || {
                    let reader = dataset.reader(cache).unwrap();
                    let mut rng = Xoshiro256::seed_from_u64(0x57E5 + t as u64);
                    for q in 0..30 {
                        let (r0, r1) = span(&mut rng, n);
                        let (c0, c1) = span(&mut rng, n);
                        let got = reader.rect(r0..r1, c0..c1).unwrap();
                        let want = rect_filter(reference, (r0, r1), (c0, c1));
                        assert_eq!(got, want, "thread {t} query {q}");
                    }
                });
            }
        });
        // A repeated whole-matrix query after eviction answers correctly.
        let reader = dataset.reader(&cache).unwrap();
        let got = reader.rect(0..n, 0..n).unwrap();
        assert_eq!(&got, &reference);
        let st = cache.stats();
        assert!(
            st.evictions > 0,
            "working set {ws} through budget {budget} must evict: {st:?}"
        );
        assert!(
            st.resident_bytes <= budget,
            "residency beyond budget: {st:?}"
        );
        // `claimed_bytes` vs `resident_bytes`: residency is what the
        // budget bounds; claimed is what is actually alive. With no
        // queries in flight only the cache's own Arcs remain, so the two
        // must agree exactly.
        assert_eq!(
            st.claimed_bytes, st.resident_bytes,
            "idle cache: claimed must equal resident: {st:?}"
        );
        // Hold a whole file's blocks while they get evicted out from
        // under us: residency stays budget-bounded, but the held Arcs
        // keep their bytes claimed beyond it.
        let held = reader.file_blocks(0).unwrap();
        assert!(!held.is_empty());
        let held_bytes: u64 = held.iter().map(|b| b.decoded_bytes()).sum();
        let st = cache.stats();
        assert!(st.resident_bytes <= budget, "budget must still bound residency: {st:?}");
        assert!(
            st.claimed_bytes >= st.resident_bytes,
            "claimed can never undercount residency: {st:?}"
        );
        assert!(
            st.claimed_bytes >= held_bytes,
            "every held block stays claimed (held {held_bytes}): {st:?}"
        );
        drop(held);
        let st = cache.stats();
        assert_eq!(
            st.claimed_bytes, st.resident_bytes,
            "releasing the held Arcs must return claimed to resident: {st:?}"
        );
        // Temporal locality survives the pressure: an immediate repeat
        // of a known-nonempty one-element rect is answered from
        // residency (its block is the most recently used, and one block
        // always fits the quarter-size budget).
        let (fi, fj, _) = reference[0];
        let one = reader.rect(fi..fi + 1, fj..fj + 1).unwrap();
        assert!(!one.is_empty());
        let st1 = cache.stats();
        let one2 = reader.rect(fi..fi + 1, fj..fj + 1).unwrap();
        assert_eq!(one, one2);
        let st2 = cache.stats();
        assert_eq!(st2.misses, st1.misses, "immediate repeat must not re-decode");
        assert!(st2.hits > st1.hits, "immediate repeat must hit: {st2:?}");
        tx.send(()).unwrap();
    });
    match rx.recv_timeout(Duration::from_secs(60)) {
        Ok(()) => worker.join().unwrap(),
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
            panic!("serve stress did not finish within 60 s (deadlock?)")
        }
        // The worker panicked before signalling: surface its panic.
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
            if let Err(e) = worker.join() {
                std::panic::resume_unwind(e);
            }
        }
    }
}

/// Single-flight: with block size = matrix size the dataset is ONE
/// block, so 8 threads racing the same whole-matrix query must record
/// exactly one miss (one decode); everyone else hits or coalesces onto
/// the in-flight slot.
#[test]
fn single_flight_records_one_miss() {
    let storage: Arc<dyn Storage> = Arc::new(MemFs::new());
    let (dataset, reference, n) = setup(storage, "flight", 1, 64);
    {
        let probe = BlockCache::with_budget(u64::MAX);
        let r = dataset.reader(&probe).unwrap();
        let _ = r.rect(0..n, 0..n).unwrap();
        assert_eq!(
            probe.stats().resident_blocks,
            1,
            "the whole matrix must be one block for this test"
        );
    }
    let cache = BlockCache::with_budget(64 << 20);
    let threads = 8;
    let barrier = std::sync::Barrier::new(threads);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let cache = &cache;
            let dataset = &dataset;
            let reference = &reference;
            let barrier = &barrier;
            scope.spawn(move || {
                let reader = dataset.reader(cache).unwrap();
                barrier.wait();
                let got = reader.rect(0..n, 0..n).unwrap();
                assert_eq!(&got, reference);
            });
        }
    });
    let st = cache.stats();
    assert_eq!(
        st.misses, 1,
        "concurrent same-block queries must decode exactly once: {st:?}"
    );
    assert_eq!(
        st.hits + st.coalesced_waits,
        threads as u64 - 1,
        "every other claim hits or coalesces: {st:?}"
    );
    assert_eq!(st.evictions, 0);
}

/// Two datasets served through one cache never cross-contaminate: each
/// reader answers from its own blocks.
#[test]
fn two_datasets_share_one_cache_without_collisions() {
    let storage: Arc<dyn Storage> = Arc::new(MemFs::new());
    let (ds_a, ref_a, n) = setup(Arc::clone(&storage), "multi-a", 2, 8);
    let (ds_b, ref_b, _) = setup(storage, "multi-b", 3, 16);
    let cache = BlockCache::with_budget(64 << 20);
    let ra = ds_a.reader(&cache).unwrap();
    let rb = ds_b.reader(&cache).unwrap();
    assert_eq!(ra.rect(0..n, 0..n).unwrap(), ref_a);
    assert_eq!(rb.rect(0..n, 0..n).unwrap(), ref_b);
    // Warm re-reads stay correct and answer from the cache.
    let st_before = cache.stats();
    assert_eq!(ra.rect(0..n, 0..n).unwrap(), ref_a);
    assert_eq!(rb.rect(0..n, 0..n).unwrap(), ref_b);
    let st_after = cache.stats();
    assert_eq!(st_before.misses, st_after.misses, "warm pass must not miss");
}

/// The closed-loop harness completes, reports sane numbers, and its
/// query stream is reproducible from the seed.
#[test]
fn closed_loop_harness_reports() {
    let storage: Arc<dyn Storage> = Arc::new(MemFs::new());
    let (dataset, _, _) = setup(storage, "loop", 2, 8);
    let cache = BlockCache::with_budget(1 << 20);
    let cfg = abhsf::serve::ServeConfig {
        threads: 4,
        queries: 64,
        seed: 9,
        spmv_every: 8,
        workload: abhsf::serve::Workload::Uniform,
    };
    let report =
        abhsf::serve::run_closed_loop(std::slice::from_ref(&dataset), &cache, &cfg).unwrap();
    assert_eq!(report.queries, 64);
    assert_eq!(report.threads, 4);
    assert!(report.spmv_queries > 0);
    assert!(report.wall_s > 0.0);
    assert!(report.qps() > 0.0);
    assert!(report.p50_ms <= report.p99_ms);
    assert!(report.p99_ms <= report.max_ms);
    assert!(report.elements_returned > 0);
    let st = report.cache;
    assert!(st.hits + st.misses > 0, "no blocks ever claimed: {st:?}");
    // Same seed, fresh cache: the same total work is issued.
    let cache2 = BlockCache::with_budget(1 << 20);
    let report2 =
        abhsf::serve::run_closed_loop(std::slice::from_ref(&dataset), &cache2, &cfg).unwrap();
    assert_eq!(report.elements_returned, report2.elements_returned);
    assert_eq!(report.spmv_queries, report2.spmv_queries);
    assert_eq!(report.per_dataset.len(), 1);
    let (_, ds) = &report.per_dataset[0];
    assert_eq!(
        ds.hits + ds.decode_saves + ds.misses,
        st.hits + st.decode_saves + st.misses,
        "single dataset: per-dataset traffic must equal the aggregate"
    );
}

/// Scan resistance, differential: the hot rect-query hit rate of a
/// seeded closed loop with a whole-matrix SpMV sweep before every round
/// stays within a fixed margin of the sweep-free loop at the same
/// budget. Under plain LRU every sweep flushes the hot set (each sweep
/// touches the entire working set, twice the budget); under 2Q the hot
/// blocks sit in the protected queue and the sweeps churn probation
/// only.
#[test]
fn sweeps_keep_hot_rect_hit_rate() {
    let storage: Arc<dyn Storage> = Arc::new(MemFs::new());
    let (dataset, _, n) = setup(storage, "scan", 4, 8);
    let probe = BlockCache::with_budget(u64::MAX);
    let _ = dataset.reader(&probe).unwrap().rect(0..n, 0..n).unwrap();
    let ws = probe.stats().resident_bytes;

    // Four small disjoint hot rectangles (≈ one block each — far below
    // the protected-queue cap at half the working set).
    let hot: Vec<(u64, u64)> = (0..4).map(|k| (k * n / 4, k * n / 4 + n.div_ceil(8))).collect();
    let hot_rate = |sweep: bool| -> f64 {
        let cache = BlockCache::with_budget_sharded(ws / 2, 1);
        let reader = dataset.reader(&cache).unwrap();
        // Warm the hot set twice: first touch admits to probation, the
        // second promotes to the protected queue.
        for _ in 0..2 {
            for &(lo, hi) in &hot {
                let _ = reader.rect(lo..hi, lo..hi).unwrap();
            }
        }
        let x: Vec<f64> = vec![1.0; n as usize];
        let (mut served, mut claims) = (0u64, 0u64);
        for _ in 0..6 {
            if sweep {
                // A whole-matrix streaming pass — the scan that would
                // flush the hot set under plain LRU.
                let _ = reader.spmv(&x).unwrap();
            }
            for &(lo, hi) in &hot {
                let before = cache.stats();
                let _ = reader.rect(lo..hi, lo..hi).unwrap();
                let after = cache.stats();
                served += after.hits - before.hits;
                claims += (after.hits - before.hits) + (after.misses - before.misses);
            }
        }
        assert!(claims > 0);
        served as f64 / claims as f64
    };
    let base = hot_rate(false);
    let with_sweeps = hot_rate(true);
    assert!(
        base > 0.99,
        "sweep-free hot set must serve from residency, got {base}"
    );
    assert!(
        with_sweeps >= base - 0.05,
        "sweeps flushed the hot set: {with_sweeps} vs sweep-free {base}"
    );
}

/// Two-tier serving: with T1 far below the working set but T2 sized to
/// hold the overflow, a warm repeat of the whole-matrix query is served
/// entirely from memory — T1 hits plus T2 re-decodes, zero storage I/O
/// — and the revived elements are identical.
#[test]
fn two_tier_warm_pass_never_touches_storage() {
    let storage: Arc<dyn Storage> = Arc::new(MemFs::new());
    let (dataset, reference, n) = setup(storage, "tiered", 4, 8);
    let probe = BlockCache::with_budget(u64::MAX);
    let _ = dataset.reader(&probe).unwrap().rect(0..n, 0..n).unwrap();
    let ws = probe.stats().resident_bytes;

    let cache = BlockCache::with_tiered_budget_sharded(ws / 4, ws, 1);
    let reader = dataset.reader(&cache).unwrap();
    assert_eq!(reader.rect(0..n, 0..n).unwrap(), reference);
    let st = cache.stats();
    assert!(st.evictions > 0, "quarter-size T1 must evict: {st:?}");
    assert!(st.demotions > 0, "evictions must demote into T2: {st:?}");
    assert!(st.t2_resident_blocks > 0, "{st:?}");
    let io_cold = reader.io_stats();
    let misses_cold = st.misses;
    // Warm pass: every block is either T1-resident or revivable from T2.
    assert_eq!(reader.rect(0..n, 0..n).unwrap(), reference);
    let st = cache.stats();
    let io_warm = reader.io_stats();
    assert_eq!(
        (io_cold.bytes, io_cold.ops),
        (io_warm.bytes, io_warm.ops),
        "warm two-tier pass touched storage: {st:?}"
    );
    assert_eq!(st.misses, misses_cold, "a T2 revival must not count as a miss: {st:?}");
    assert!(st.decode_saves > 0, "warm pass must revive from T2: {st:?}");
    assert!(st.resident_bytes <= ws / 4, "T1 budget violated: {st:?}");
    assert!(st.t2_resident_bytes <= ws, "T2 budget violated: {st:?}");
}

/// The planner's directory-measured footprint must agree exactly with
/// the byte accounting the cache applies to fully resident blocks.
#[test]
fn measured_footprint_matches_cache_accounting() {
    use abhsf::cache::DatasetFootprint;
    let storage: Arc<dyn Storage> = Arc::new(MemFs::new());
    let (dataset, _, n) = setup(Arc::clone(&storage), "footprint", 3, 8);
    let (blocks, native, _) = accounting_for(&storage, &dataset);
    let fp = DatasetFootprint::measure(&dataset).unwrap();
    assert_eq!(fp.blocks, blocks);
    assert_eq!(
        fp.decoded_bytes, native,
        "footprint must reproduce the cache's per-scheme accounting"
    );
    assert!(fp.encoded_bytes < fp.decoded_bytes, "{fp:?}");
    // And the real cache agrees: ample budget, everything resident.
    let cache = BlockCache::with_budget(u64::MAX);
    let _ = dataset.reader(&cache).unwrap().rect(0..n, 0..n).unwrap();
    assert_eq!(cache.stats().resident_bytes, fp.decoded_bytes);
}
