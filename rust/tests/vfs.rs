//! Storage-virtualization integration: the same store/load/repack stack
//! over every backend, and the [`SimFs`] fault-injection suite — a
//! truncated container, a missing per-rank file and a failed manifest
//! write must each surface as a *typed* [`DatasetError`] (no panics) and
//! never leave a partial `dataset.json` behind.

use std::path::Path;
use std::sync::Arc;

use abhsf::coordinator::{
    Cluster, Dataset, DatasetError, InMemFormat, LoadedMatrix, StoreOptions, Strategy,
    MANIFEST_FILE,
};
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::mapping::{Colwise, ProcessMapping, Rowwise};
use abhsf::parfs::FsModel;
use abhsf::vfs::{FaultSpec, MemFs, SimFs, Storage};

const P: usize = 3;
const DIR: &str = "/vfs-test/matrix";

/// Store a small matrix on a fresh MemFs; returns the map and the
/// dataset handle bound to it.
fn mem_dataset() -> (MemFs, Dataset) {
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 11), 2));
    let n = gen.dim();
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, P));
    let cluster = Cluster::new(P, 64);
    let mem = MemFs::new();
    let (dataset, report) = Dataset::store_on(
        Arc::new(mem.clone()),
        &cluster,
        &gen,
        &mapping,
        DIR,
        StoreOptions {
            block_size: 8,
            chunk_elems: 256,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(report.total_nnz() > 0);
    (mem, dataset)
}

/// Reopen the MemFs dataset through a SimFs with the given faults.
fn sim_view(mem: &MemFs, faults: &str) -> Arc<SimFs> {
    Arc::new(
        SimFs::new(Arc::new(mem.clone()), FsModel::local_nvme())
            .faults(FaultSpec::parse(faults).unwrap()),
    )
}

fn collect(mats: &[LoadedMatrix]) -> Vec<(u64, u64, f64)> {
    let mut out = Vec::new();
    for lm in mats {
        let coo = lm.clone().into_coo();
        let (ro, co) = (coo.info.m_offset, coo.info.n_offset);
        for (i, j, v) in coo.iter() {
            out.push((i + ro, j + co, v));
        }
    }
    out.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    out
}

// ---------------------------------------------------------------- load

/// A truncated container fails the load with a typed error — every
/// strategy, no panic, no hand-corrupted files needed.
#[test]
fn truncated_container_is_typed_error_on_load() {
    let (mem, _) = mem_dataset();
    let sim = sim_view(&mem, "truncate:matrix-0");
    let dataset = Dataset::open_on(sim, DIR).unwrap();
    let n = dataset.dims().0;
    // All-read-all strategies fail on the shared first file, so every
    // rank errors symmetrically. (The exchange loader is exercised via
    // the *missing* fault below: its peers wait on Done messages an
    // erroring reader never sends, so a mid-read fault is a routing-
    // protocol liveness question, not a storage-error-typing one.)
    for (strategy, p_load) in [(Strategy::Independent, 2usize), (Strategy::Collective, 2)] {
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, p_load));
        let cluster = Cluster::new(p_load, 8);
        let err = dataset
            .load()
            .mapping(&mapping)
            .strategy(strategy)
            .run(&cluster)
            .expect_err("truncated container must fail the load");
        // Typed (matchable) and descriptive, not a panic.
        assert!(
            matches!(err, DatasetError::Internal(_)),
            "{strategy}: {err}"
        );
    }
    // Same-config fast path too.
    let cluster = Cluster::new(P, 8);
    assert!(dataset.load().run(&cluster).is_err());
}

/// A missing per-rank file surfaces as `DatasetError::MissingFile`
/// naming the absent path, before any worker runs — for every strategy,
/// including exchange (the planner's up-front check is what keeps a
/// mid-exchange disappearance from wedging the routing protocol).
#[test]
fn missing_file_is_typed_error_on_load() {
    let (mem, _) = mem_dataset();
    let sim = sim_view(&mem, "missing:matrix-1");
    let dataset = Dataset::open_on(sim, DIR).unwrap();
    let n = dataset.dims().0;
    for strategy in [Strategy::Auto, Strategy::Exchange] {
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, P));
        let cluster = Cluster::new(P, 8);
        let err = dataset
            .load()
            .mapping(&mapping)
            .strategy(strategy)
            .run(&cluster)
            .expect_err("missing container must fail the plan");
        match err {
            DatasetError::MissingFile { path, .. } => {
                assert!(path.ends_with("matrix-1.h5spm"), "{}", path.display());
            }
            other => panic!("{strategy}: expected MissingFile, got {other}"),
        }
    }
}

// --------------------------------------------------------------- store

/// A failed manifest write fails the store with a typed error and leaves
/// NO partial `dataset.json` behind — a dataset directory either has a
/// complete manifest or none.
#[test]
fn failed_manifest_write_leaves_no_partial_manifest() {
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 11), 2));
    let n = gen.dim();
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, P));
    let cluster = Cluster::new(P, 64);
    let mem = MemFs::new();
    let sim = sim_view(&mem, "fail-writes:dataset.json");
    let err = Dataset::store_on(sim, &cluster, &gen, &mapping, DIR, StoreOptions::default())
        .expect_err("manifest write fault must fail the store");
    assert!(matches!(err, DatasetError::Internal(_)), "{err}");
    assert!(
        mem.read_file(&Path::new(DIR).join(MANIFEST_FILE)).is_err(),
        "failed manifest write left a dataset.json behind"
    );
}

/// A failed container write fails the store before the manifest is ever
/// attempted: typed error, no `dataset.json`.
#[test]
fn failed_container_write_is_typed_error_on_store() {
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 11), 2));
    let n = gen.dim();
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, P));
    let cluster = Cluster::new(P, 64);
    let mem = MemFs::new();
    let sim = sim_view(&mem, "fail-writes:matrix-1");
    let err = Dataset::store_on(sim, &cluster, &gen, &mapping, DIR, StoreOptions::default())
        .expect_err("container write fault must fail the store");
    assert!(matches!(err, DatasetError::Internal(_)), "{err}");
    assert!(
        mem.read_file(&Path::new(DIR).join(MANIFEST_FILE)).is_err(),
        "store failed but a manifest was written"
    );
}

// -------------------------------------------------------------- repack

/// Repack read phase: a truncated source container is a typed error.
#[test]
fn truncated_source_is_typed_error_on_repack() {
    let (mem, _) = mem_dataset();
    let sim = sim_view(&mem, "truncate:matrix-2");
    let dataset = Dataset::open_on(sim, DIR).unwrap();
    let n = dataset.dims().0;
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, 2));
    let cluster = Cluster::new(2, 8);
    let err = dataset
        .repack()
        .nprocs(2)
        .mapping(&mapping)
        .run(&cluster, "/vfs-test/out")
        .expect_err("truncated source must fail the repack");
    assert!(matches!(err, DatasetError::Internal(_)), "{err}");
}

/// Repack write phase: a failed output manifest write is a typed error
/// and leaves no partial `dataset.json` in the output directory.
#[test]
fn failed_output_writes_are_typed_errors_on_repack() {
    let (mem, dataset) = mem_dataset();
    let n = dataset.dims().0;
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, 2));
    let cluster = Cluster::new(2, 8);

    // Container writes fail.
    let out_faulty = sim_view(&mem, "fail-writes:out-a/matrix");
    let err = dataset
        .repack()
        .nprocs(2)
        .mapping(&mapping)
        .storage(out_faulty)
        .run(&cluster, "/vfs-test/out-a")
        .expect_err("output container fault must fail the repack");
    assert!(matches!(err, DatasetError::Internal(_)), "{err}");
    assert!(
        mem.read_file(&Path::new("/vfs-test/out-a").join(MANIFEST_FILE))
            .is_err(),
        "failed repack left a manifest"
    );

    // Only the manifest write fails (containers land).
    let out_manifest_faulty = sim_view(&mem, "fail-writes:out-b/dataset.json");
    let err = dataset
        .repack()
        .nprocs(2)
        .mapping(&mapping)
        .storage(out_manifest_faulty)
        .run(&cluster, "/vfs-test/out-b")
        .expect_err("output manifest fault must fail the repack");
    assert!(matches!(err, DatasetError::Internal(_)), "{err}");
    assert!(
        mem.read_file(&Path::new("/vfs-test/out-b").join(MANIFEST_FILE))
            .is_err(),
        "failed manifest write left a dataset.json behind"
    );
}

// ------------------------------------------- backend equivalence + misc

/// Repack migrates a dataset *between* media: read from one MemFs, write
/// to another, element-identical; and the into-source refusal keys on
/// the medium, not just the path.
#[test]
fn repack_migrates_across_backends() {
    let (_, dataset) = mem_dataset();
    let cluster = Cluster::new(P, 8);
    let (orig, _) = dataset
        .load()
        .format(InMemFormat::Coo)
        .run(&cluster)
        .unwrap();

    // Same path, same medium: refused.
    let err = dataset.repack().run(&cluster, DIR).unwrap_err();
    assert!(matches!(err, DatasetError::RepackIntoSource { .. }), "{err}");

    // Same path, different medium: a migration, not a clobber.
    let other = MemFs::new();
    let (migrated, report) = dataset
        .repack()
        .storage(Arc::new(other.clone()))
        .run(&cluster, DIR)
        .unwrap();
    assert_eq!(report.total_nnz(), dataset.nnz());
    assert!(other.total_bytes() > 0);
    let reopened = Dataset::open_on(Arc::new(other), DIR).unwrap();
    assert_eq!(reopened.manifest(), migrated.manifest());
    let (mats, _) = reopened
        .load()
        .format(InMemFormat::Coo)
        .run(&cluster)
        .unwrap();
    assert_eq!(collect(&mats), collect(&orig), "migration diverged");
}

/// A fault-free SimFs is behaviorally transparent: the load succeeds
/// element-identically and the simulated clock has advanced by the
/// parfs-model cost of the traffic.
#[test]
fn faultless_sim_is_transparent_and_accounts_cost() {
    let (mem, dataset) = mem_dataset();
    let cluster = Cluster::new(P, 8);
    let (plain, _) = dataset
        .load()
        .format(InMemFormat::Coo)
        .run(&cluster)
        .unwrap();

    let sim = sim_view(&mem, "");
    let viewed = Dataset::open_on(Arc::clone(&sim) as Arc<dyn Storage>, DIR).unwrap();
    let (mats, report) = viewed
        .load()
        .format(InMemFormat::Coo)
        .run(&cluster)
        .unwrap();
    assert_eq!(collect(&mats), collect(&plain));
    let floor = report.total_read_bytes() as f64 / FsModel::local_nvme().client_bps;
    assert!(
        sim.simulated_seconds() >= floor,
        "sim clock {} below bandwidth floor {floor}",
        sim.simulated_seconds()
    );
}
